"""Serving metrics (EXPERIMENTS.md §Serving).

Latency accounting follows the serving-benchmark conventions: everything is
measured from *arrival* (a queued request is already costing its user time):

  TTFT     first_token_s − arrival_s    (queueing + prefill + first decode)
  latency  finish_s − arrival_s         (end-to-end per request)
  ms/token span_s / total generated tokens × 1e3 (fleet-level pace)

Percentiles use the nearest-rank method — exact for the small request
counts benchmarks run, no interpolation surprises.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence

# Bump when ServingReport gains/loses/renames fields. Baseline JSONs under
# benchmarks/baselines/ carry the version they were generated with;
# report_from_dict warns on mismatch instead of KeyError-ing so old
# baselines stay loadable across schema growth.
SCHEMA_VERSION = 2


def percentile(values: Sequence[float], p: float) -> float:
    """Nearest-rank percentile; NaN for empty input."""
    if not values:
        return float("nan")
    xs = sorted(values)
    k = max(math.ceil(p / 100.0 * len(xs)) - 1, 0)
    return xs[min(k, len(xs) - 1)]


@dataclasses.dataclass
class ServingReport:
    pattern: str
    backend: str
    n_requests: int
    n_rejected: int                # shed at intake (queue/KV oversize)
    total_tokens: int
    span_s: float                  # first arrival -> last completion
    ms_per_token: float
    throughput_tok_s: float
    throughput_req_s: float
    ttft_p50_s: float
    ttft_p99_s: float
    latency_p50_s: float
    latency_p99_s: float
    # TTFT split (DESIGN.md §12): time stuck in the queue vs time spent
    # prefilling after admission — a prefix hit shrinks the second term,
    # better scheduling the first
    ttft_queue_p50_s: float = float("nan")
    ttft_queue_p99_s: float = float("nan")
    ttft_prefill_p50_s: float = float("nan")
    ttft_prefill_p99_s: float = float("nan")
    # per-request decode pace: generated tokens / (finish - first token),
    # the steady-state rate users see after TTFT (NaN when no request
    # decoded more than one token)
    decode_tok_s_p50: float = float("nan")
    decode_tok_s_p99: float = float("nan")
    # paged-KV accounting (DESIGN.md §10; zero under reservation policy)
    n_preempted: int = 0           # preemption events (spill or recompute)
    peak_active: int = 0           # max co-resident requests
    peak_kv_pages: int = 0         # max device-tier pages in use
    kv_pages_spilled: int = 0
    kv_pages_fetched: int = 0
    kv_migrated_bytes: float = 0.0
    # speculative decoding (DESIGN.md §11; zero when spec is off)
    spec_rounds: int = 0
    spec_drafted: int = 0
    spec_accepted: int = 0
    spec_acceptance_rate: float = 0.0
    # radix prefix cache (DESIGN.md §12; zero when the cache is off)
    prefix_hit_rate: float = 0.0   # admissions that matched a cached prefix
    cached_tokens: int = 0         # tokens held by the radix tree at end
    prefill_tokens_saved: int = 0  # prompt tokens served from cache instead
                                   # of riding a prefill round
    # online memory adaptation (DESIGN.md §13; zero when --adapt is off)
    retier_events: int = 0         # tier moves fired (planner + reclaim)
    layers_demoted: int = 0        # resident layers moved to the streamed
                                   # tier (whole-layer equivalents)
    layers_promoted: int = 0       # moved back when pressure dropped
    hbm_returned_bytes: float = 0.0  # weight HBM credited to the KV pool
    retier_reclaimed_pages: int = 0  # pages granted by scheduler-driven
                                     # reclaim (before any preemption)
    # schema versioning (satellite of DESIGN.md §15): benchmark JSON is
    # compared across PRs — a version stamp lets readers warn instead of
    # KeyError when the field set moves under them
    schema_version: int = SCHEMA_VERSION

    def to_dict(self) -> Dict:
        # NaN (empty-percentile sentinel) is not valid JSON — json.dumps
        # happily emits a bare `NaN` token that strict parsers (jq,
        # browsers, other languages) reject. Serialize it as null;
        # report_from_dict maps null back to NaN on the way in.
        return {k: (None if isinstance(v, float) and math.isnan(v) else v)
                for k, v in dataclasses.asdict(self).items()}

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent,
                          allow_nan=False)


def summarize(requests: List, *, pattern: str = "", backend: str = "",
              stats: Optional[Dict] = None) -> ServingReport:
    """Build a ServingReport from served request records (anything with
    arrival_s / first_token_s / finish_s / output / rejected attributes).
    `stats`: the scheduler's counters — a plain dict or a
    repro.obs.MetricsRegistry (the report is a derived view either way,
    field-identical by construction)."""
    served = [r for r in requests if not getattr(r, "rejected", False)
              and r.finish_s is not None]
    rejected = [r for r in requests if getattr(r, "rejected", False)]
    if stats is not None and hasattr(stats, "to_stats_dict"):
        stats = stats.to_stats_dict()
    stats = stats or {}
    total_tokens = sum(getattr(r, "generated", 0) or len(r.output)
                      for r in served)
    if served:
        t0 = min(r.arrival_s for r in served)
        t1 = max(r.finish_s for r in served)
        span = max(t1 - t0, 1e-12)
    else:
        span = 0.0
    ttfts = [r.first_token_s - r.arrival_s for r in served
             if r.first_token_s is not None]
    lats = [r.finish_s - r.arrival_s for r in served]
    # TTFT split: arrival -> admission (queue wait) and admission ->
    # first token (prefill compute + any preemption detour)
    queues = [r.admitted_s - r.arrival_s for r in served
              if getattr(r, "admitted_s", None) is not None]
    prefills = [r.first_token_s - r.admitted_s for r in served
                if getattr(r, "admitted_s", None) is not None
                and r.first_token_s is not None]
    # p50/p99 of per-request decode pace; the first token belongs to TTFT,
    # the remaining generated-1 span first_token_s..finish_s
    rates = [(r.generated - 1) / max(r.finish_s - r.first_token_s, 1e-12)
             for r in served
             if r.first_token_s is not None
             and getattr(r, "generated", 0) > 1]
    # acceptance is DERIVED from the raw counters (single source of
    # truth) — a pre-computed stats entry is ignored, not trusted
    spec_drafted = int(stats.get("spec_drafted", 0))
    spec_accepted = int(stats.get("spec_accepted", 0))
    return ServingReport(
        pattern=pattern, backend=backend,
        n_requests=len(served), n_rejected=len(rejected),
        total_tokens=total_tokens, span_s=span,
        ms_per_token=(1e3 * span / total_tokens if total_tokens
                      else float("nan")),
        throughput_tok_s=(total_tokens / span if span else 0.0),
        throughput_req_s=(len(served) / span if span else 0.0),
        ttft_p50_s=percentile(ttfts, 50), ttft_p99_s=percentile(ttfts, 99),
        latency_p50_s=percentile(lats, 50),
        latency_p99_s=percentile(lats, 99),
        ttft_queue_p50_s=percentile(queues, 50),
        ttft_queue_p99_s=percentile(queues, 99),
        ttft_prefill_p50_s=percentile(prefills, 50),
        ttft_prefill_p99_s=percentile(prefills, 99),
        decode_tok_s_p50=percentile(rates, 50),
        decode_tok_s_p99=percentile(rates, 99),
        n_preempted=sum(getattr(r, "preempted", 0) for r in requests),
        spec_rounds=int(stats.get("spec_rounds", 0)),
        spec_drafted=spec_drafted,
        spec_accepted=spec_accepted,
        spec_acceptance_rate=(spec_accepted / spec_drafted
                              if spec_drafted else 0.0),
        prefix_hit_rate=(float(stats.get("prefix_hits", 0))
                         / max(float(stats.get("prefix_lookups", 0)), 1.0)),
        cached_tokens=int(stats.get("cached_tokens", 0)),
        prefill_tokens_saved=int(stats.get("prefill_tokens_saved", 0)),
        retier_events=int(stats.get("retier_events", 0)),
        layers_demoted=int(stats.get("layers_demoted", 0)),
        layers_promoted=int(stats.get("layers_promoted", 0)),
        hbm_returned_bytes=float(stats.get("hbm_returned_bytes", 0.0)),
        retier_reclaimed_pages=int(stats.get("retier_reclaimed_pages", 0)),
        peak_active=int(stats.get("peak_active", 0)),
        peak_kv_pages=int(stats.get("peak_kv_pages", 0)),
        kv_pages_spilled=int(stats.get("kv_pages_spilled", 0)),
        kv_pages_fetched=int(stats.get("kv_pages_fetched", 0)),
        kv_migrated_bytes=float(stats.get("kv_migrated_bytes", 0.0)))


def report_from_dict(d: Dict, *, source: str = "",
                     warn=None) -> ServingReport:
    """Rehydrate a ServingReport from benchmark/baseline JSON,
    tolerantly: missing fields fall back to dataclass defaults, unknown
    fields are dropped, and a schema_version mismatch warns (via `warn`
    or repro.obs.log) instead of raising — old baselines stay readable
    across schema growth (DESIGN.md §15 satellite)."""
    if warn is None:
        from repro.obs.log import get_logger
        warn = get_logger("repro.metrics").warning
    fields = {f.name: f for f in dataclasses.fields(ServingReport)}
    ver = d.get("schema_version")
    if ver != SCHEMA_VERSION:
        warn("baseline schema mismatch", source=source or "<dict>",
             baseline=ver, current=SCHEMA_VERSION)
    unknown = sorted(set(d) - set(fields))
    if unknown:
        warn("baseline has unknown report fields (dropped)",
             source=source or "<dict>", fields=",".join(unknown))
    required = {"pattern", "backend", "n_requests", "n_rejected",
                "total_tokens", "span_s", "ms_per_token",
                "throughput_tok_s", "throughput_req_s", "ttft_p50_s",
                "ttft_p99_s", "latency_p50_s", "latency_p99_s"}
    missing = sorted(required - set(d))
    if missing:
        warn("baseline missing report fields (defaults used)",
             source=source or "<dict>", fields=",".join(missing))
    kw = {k: v for k, v in d.items() if k in fields}
    # null in the JSON is the wire form of an empty-percentile NaN
    # (to_dict wrote it); restore the float sentinel for numeric fields
    for name, v in list(kw.items()):
        if v is None and str(fields[name].type) == "float":
            kw[name] = float("nan")
    fill = {"str": "", "int": 0, "float": float("nan")}
    for name in required - set(kw):
        kw[name] = fill.get(str(fields[name].type), 0)
    return ServingReport(**kw)
