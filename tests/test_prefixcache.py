"""Radix prefix-cache subsystem (DESIGN.md §12): tree match/insert/evict
invariants, COW admission through the paged manager, scheduler integration
(hits, chunked prefill, leak-freedom), and the engine-tier losslessness
contract (prefix-hit decode token-identical to cold; chunked prefill
bitwise-equal to monolithic)."""
import pytest

from repro.kvcache import BlockTable, PagedKVConfig, PagedKVManager, PagePool
from repro.kvcache.pool import DEVICE, HOST
from repro.prefixcache import RadixPrefixCache


def _pool(dev=16, host=8, ps=4, page_bytes=8.0):
    return PagePool(PagedKVConfig(page_size=ps, device_pages=dev,
                                  host_pages=host, page_bytes=page_bytes))


def _table(pool, tokens):
    t = BlockTable(pool.page_size)
    pool.extend_table(t, tokens)
    return t


# ----------------------------------------------------------------------------
# radix tree: match / insert / evict
# ----------------------------------------------------------------------------
def test_radix_insert_match_page_aligned():
    pool = _pool()
    tree = RadixPrefixCache(pool)
    toks = list(range(100, 110))        # 10 tokens, ps=4 -> 2 full pages
    t = _table(pool, 10)
    assert tree.insert(toks, t.pages) == 2
    assert tree.n_pages == 2
    # full match returns both pages; the partial last page never caches
    pages, n = tree.match(toks)
    assert n == 8 and pages == t.pages[:2]
    # max_pages cap (admission leaves >= 1 token to prefill)
    pages, n = tree.match(toks, max_pages=1)
    assert n == 4 and pages == t.pages[:1]
    # diverging second page: only the first page matches
    other = toks[:4] + [999] * 6
    pages, n = tree.match(other)
    assert n == 4 and pages == t.pages[:1]
    # no match at all
    assert tree.match([7, 7, 7, 7, 7])[1] == 0
    pool.release_table(t)
    assert pool.alloc.used_pages == tree.n_pages == 2


def test_radix_insert_increfs_pages_outlive_table():
    pool = _pool()
    tree = RadixPrefixCache(pool)
    toks = list(range(8))
    t = _table(pool, 8)
    tree.insert(toks, t.pages)
    assert pool.alloc.refcount(t.pages[0]) == 2
    pool.release_table(t)
    assert pool.alloc.used_pages == 2   # the tree still owns them
    pages, n = tree.match(toks, max_pages=1)
    assert n == 4
    tree.release_all()
    assert pool.alloc.used_pages == 0


def test_radix_insert_existing_key_keeps_first_copy():
    pool = _pool()
    tree = RadixPrefixCache(pool)
    toks = list(range(8))
    a, b = _table(pool, 8), _table(pool, 8)
    assert tree.insert(toks, a.pages) == 2
    assert tree.insert(toks, b.pages) == 0      # same keys: first wins
    assert tree.match(toks)[0] == a.pages[:2]
    assert pool.alloc.refcount(b.pages[0]) == 1  # b's copy not adopted
    pool.release_table(a)
    pool.release_table(b)
    tree.release_all()
    assert pool.alloc.used_pages == 0


def test_radix_evict_lru_leaves_and_refcount_pinning():
    pool = _pool(dev=16)
    tree = RadixPrefixCache(pool)
    t1 = _table(pool, 8)                # stream A: 2 pages
    t2 = _table(pool, 8)                # stream B: 2 pages
    a = [1, 1, 1, 1, 2, 2, 2, 2]
    b = [3, 3, 3, 3, 4, 4, 4, 4]
    tree.insert(a, t1.pages)
    tree.insert(b, t2.pages)
    pool.release_table(t1)              # A unpinned
    tree.match(a)                       # A recently used; B is LRU...
    # ...but B is pinned by t2, so eviction must take A's leaf instead
    assert tree.evict(1) == 1
    assert tree.match(a)[1] == 4        # A's leaf gone, root page stays
    assert tree.match(b)[1] == 8        # pinned B untouched
    pool.release_table(t2)
    assert tree.evict(10) == 3          # everything else reclaimable
    assert tree.n_pages == 0 and pool.alloc.used_pages == 0


def test_radix_evict_exposes_parents():
    pool = _pool()
    tree = RadixPrefixCache(pool)
    t = _table(pool, 12)                # 3-page chain
    tree.insert(list(range(12)), t.pages)
    pool.release_table(t)
    assert tree.evict(3) == 3           # leaf, then its parent, then root
    assert tree.n_pages == 0 and pool.alloc.used_pages == 0


# ----------------------------------------------------------------------------
# manager: COW admission over a matched prefix
# ----------------------------------------------------------------------------
def test_admit_with_prefix_shares_and_releases_cleanly():
    pool = _pool(dev=8)
    tree = RadixPrefixCache(pool)
    mgr = PagedKVManager(pool)
    toks = list(range(10))
    donor = _table(pool, 10)
    tree.insert(toks, donor.pages)
    pool.release_table(donor)
    pages, ctok = tree.match(toks, max_pages=(10 - 1) // 4)
    assert ctok == 8
    assert mgr.can_admit_prefix(11, pages)
    moved = mgr.admit_with_prefix(1, pages, ctok, 11)
    assert moved == 0.0                 # all matched pages on-device
    t = mgr.table(1)
    assert t.pages[:2] == pages and t.tokens == 11
    assert pool.alloc.refcount(pages[0]) == 2   # tree + table
    # COW: growth appends fresh pages, never touches shared ones
    assert mgr.extend(1, 13)
    assert t.pages[:2] == pages and len(t.pages) == 4
    mgr.release(1)
    assert pool.alloc.used_pages == tree.n_pages == 2
    tree.release_all()
    assert pool.alloc.used_pages == 0


def test_admit_with_prefix_fetches_host_pages_and_prices_them():
    pool = _pool(dev=8, host=8, page_bytes=100.0)
    tree = RadixPrefixCache(pool)
    mgr = PagedKVManager(pool)
    toks = list(range(8))
    donor = _table(pool, 8)
    tree.insert(toks, donor.pages)
    pool.release_table(donor)
    pool.migrate(tree.match(toks)[0], HOST)     # delegated cached pages
    pages, ctok = tree.match(toks, max_pages=1)
    assert pool.tier_of(pages[0]) == HOST
    moved = mgr.admit_with_prefix(5, pages, ctok, 6)
    assert moved == 100.0                       # the hit paid the fetch
    assert pool.tier_of(pages[0]) == DEVICE
    mgr.release(5)
    tree.release_all()
    assert pool.alloc.used_pages == 0


def test_can_admit_prefix_counts_suffix_only():
    pool = _pool(dev=4)
    tree = RadixPrefixCache(pool)
    mgr = PagedKVManager(pool)
    donor = _table(pool, 12)            # 3 of 4 device pages
    tree.insert(list(range(12)), donor.pages)
    pool.release_table(donor)
    pages, ctok = tree.match(list(range(12)), max_pages=3)
    # cold would need 4 pages (16 tokens) -> impossible; with the prefix
    # only 1 fresh page is needed
    assert not mgr.can_admit(13 + 1)
    assert mgr.can_admit_prefix(13 + 1, pages)
    tree.release_all()


def test_spill_keeps_shared_pages_on_device():
    """Preempt-spill must not migrate pages another owner still shares:
    the co-resident request attends them, and moving them would overstate
    free device capacity (the admission watermark would over-commit)."""
    pool = _pool(dev=8, host=8, page_bytes=10.0)
    tree = RadixPrefixCache(pool)
    mgr = PagedKVManager(pool)
    toks = list(range(12))
    donor = _table(pool, 12)
    tree.insert(toks, donor.pages)
    pool.release_table(donor)
    pages, ctok = tree.match(toks, max_pages=2)
    mgr.admit_with_prefix(1, pages, ctok, 13)       # A: 2 shared + 2 own
    mgr.admit_with_prefix(2, pages, ctok, 13)       # B shares the prefix
    a_own = [p for p in mgr.table(1).pages if p not in pages]
    moved = mgr.preempt(1, "spill")
    assert moved == len(a_own) * 10.0               # only A's own pages
    assert all(pool.tier_of(p) == DEVICE for p in pages)
    assert all(pool.tier_of(p) == HOST for p in a_own)
    assert mgr.resume(1) == len(a_own) * 10.0       # fetch only what left
    assert all(pool.tier_of(p) == DEVICE for p in mgr.table(1).pages)
    mgr.release(1)
    mgr.release(2)
    tree.release_all()
    assert pool.alloc.used_pages == 0


def test_evict_tier_aware_skips_host_pages():
    """A caller starved for device pages gains nothing from dropping
    host-tier cached leaves — tier-restricted eviction skips them (and
    untiered eviction still reclaims everything)."""
    pool = _pool(dev=8, host=8)
    tree = RadixPrefixCache(pool)
    t = _table(pool, 8)
    tree.insert(list(range(8)), t.pages)
    pool.release_table(t)
    host_page = tree.match(list(range(8)))[0][1]    # the leaf
    pool.migrate([host_page], HOST)
    assert tree.evict(1, tier=DEVICE) == 0          # leaf is host-tier,
    assert tree.n_pages == 2                        # its parent shielded
    assert tree.evict(2) == 2                       # untiered: all go
    assert pool.alloc.used_pages == 0


# ----------------------------------------------------------------------------
# scheduler integration over the simulator (sim_backend: conftest factory)
# ----------------------------------------------------------------------------
def _serve_shared(sim_backend, prefix: bool, chunk=None, budget_pages=None,
                  n_req=16, prompt=256, prefix_len=192, max_new=16):
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               make_arrivals, requests_from_arrivals,
                               summarize)

    arr = make_arrivals("shared_prefix", n_req, seed=0, n_templates=2,
                        prefix_len=prefix_len, prompt_len=prompt,
                        max_new_tokens=max_new, rate_rps=2.0)
    budget = (budget_pages * 32) if budget_pages \
        else 6 * (prompt + max_new)
    sched = ContinuousBatchingScheduler(sim_backend(4, prompt=prompt),
                                        SchedulerConfig(
        kv_budget_tokens=budget, kv_policy="paged", page_size=32,
        prefix_cache=prefix, prefill_chunk_tokens=chunk))
    done = sched.serve(requests_from_arrivals(arr))
    rep = summarize(done, pattern="shared_prefix", backend="sim",
                    stats=sched.stats)
    return sched, done, rep


def test_prefix_cache_hits_and_no_leaks(sim_backend):
    sched, done, rep = _serve_shared(sim_backend, True)
    assert all(r.done and r.generated == r.max_new_tokens for r in done
               if not r.rejected)
    assert rep.prefix_hit_rate > 0.5
    assert rep.prefill_tokens_saved > 0
    assert rep.cached_tokens == sched.prefix.n_pages * 32
    # leak-freedom: after every request released, only the radix tree
    # holds pages
    pool = sched.mgr.pool
    assert pool.alloc.used_pages == sched.prefix.n_pages
    sched.prefix.release_all()
    assert pool.alloc.used_pages == 0


def test_prefix_cache_improves_prefill_latency(sim_backend):
    _, _, cold = _serve_shared(sim_backend, False)
    _, _, warm = _serve_shared(sim_backend, True)
    assert warm.ttft_prefill_p50_s < cold.ttft_prefill_p50_s
    assert warm.ttft_p50_s < cold.ttft_p50_s


def test_prefix_cache_requires_paged_policy(sim_backend):
    from repro.serving import ContinuousBatchingScheduler, SchedulerConfig

    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(sim_backend(2), SchedulerConfig(
            kv_policy="reserve", prefix_cache=True))


def test_admission_accounts_cached_pages(sim_backend):
    """The _admits fix: a prefix hit must be admitted where a cold request
    of the same length would not fit — cached pages don't count against
    the free pool."""
    from repro.serving import (ContinuousBatchingScheduler, Request,
                               SchedulerConfig)
    from repro.serving.traffic import template_tokens

    be = sim_backend(2, prompt=96)
    # budget: 5 pages of 32 = 160 tokens; a 96+4=100-token request needs
    # 4 pages cold
    sched = ContinuousBatchingScheduler(be, SchedulerConfig(
        kv_budget_tokens=160, kv_policy="paged", page_size=32,
        prefix_cache=True))
    prompt = template_tokens(0, 96)
    r0 = Request(0, prompt.copy(), max_new_tokens=4)
    done = sched.serve([r0])
    assert done[0].done
    assert sched.prefix.n_pages == 3        # 96/32 pages donated
    # now 3 of 5 pages are cached; a cold 100-token request (4 pages)
    # could only be admitted by evicting — a hit needs just 2 fresh pages
    r1 = Request(1, prompt.copy(), max_new_tokens=4)
    pages, ctok = sched._lookup(r1)
    assert ctok == 64                       # capped below the last token
    assert sched._admits(r1)
    sched._on_admit(r1)
    assert r1.cached_tokens == 64
    assert sched.mgr.table(r1.rid).pages[:2] == pages
    sched.mgr.release(r1.rid)
    sched.prefix.release_all()
    assert sched.mgr.pool.alloc.used_pages == 0


def test_cached_pages_evicted_before_preemption(sim_backend):
    """Pool pressure reclaims unpinned radix pages first: with the tree
    holding most of a tiny pool, a burst must still complete without the
    tree deadlocking admission, and eviction must actually fire."""
    sched, done, rep = _serve_shared(sim_backend, True, budget_pages=22,
                                     n_req=12)
    assert all(r.done and r.generated == r.max_new_tokens for r in done
               if not r.rejected)
    assert sched.prefix.evicted_pages > 0
    pool = sched.mgr.pool
    assert pool.alloc.used_pages == sched.prefix.n_pages


def test_chunked_prefill_same_results_and_mixed_rounds(sim_backend):
    """Chunked prefill completes every request with its exact token count
    and emits first tokens only after the full prompt drained."""
    schedm, donem, repm = _serve_shared(sim_backend, False, chunk=None)
    schedc, donec, repc = _serve_shared(sim_backend, False, chunk=64)
    for done in (donem, donec):
        assert all(r.done and r.generated == r.max_new_tokens
                   for r in done if not r.rejected)
    served = [r for r in donec if not r.rejected]
    assert all(r.first_token_s >= r.admitted_s for r in served)
    # chunking never loses tokens vs monolithic
    assert sum(r.generated for r in donec) == sum(r.generated
                                                  for r in donem)


def test_chunked_prefill_with_prefix_hits(sim_backend):
    sched, done, rep = _serve_shared(sim_backend, True, chunk=64)
    assert all(r.done and r.generated == r.max_new_tokens for r in done
               if not r.rejected)
    assert rep.prefix_hit_rate > 0.5
    pool = sched.mgr.pool
    assert pool.alloc.used_pages == sched.prefix.n_pages


def test_multiturn_traffic_hits_grow_over_turns(sim_backend):
    from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                               make_arrivals, requests_from_arrivals,
                               summarize)

    arr = make_arrivals("multiturn", 9, seed=1, turns=3, prompt_len=64,
                        max_new_tokens=8, rate_rps=1.0)
    sched = ContinuousBatchingScheduler(sim_backend(2, prompt=64),
                                        SchedulerConfig(
        kv_policy="paged", page_size=16, prefix_cache=True))
    done = sched.serve(requests_from_arrivals(arr))
    rep = summarize(done, pattern="multiturn", backend="sim",
                    stats=sched.stats)
    assert all(r.done for r in done if not r.rejected)
    # turn >= 2 re-sends the conversation: its turn-1 prefix must hit
    assert rep.prefix_hit_rate > 0.3
    assert sched.mgr.pool.alloc.used_pages == sched.prefix.n_pages


# ----------------------------------------------------------------------------
# engine tier: losslessness of prefix-hit decode + chunked prefill
# ----------------------------------------------------------------------------
PREFIX_LOSSLESS_WORKER = r"""
import sys
import numpy as np, jax
import jax.numpy as jnp
from repro.configs.registry import get_smoke_config
from repro.models import model as M
from repro.serving import (ContinuousBatchingScheduler, EngineBackend,
                           Request, SchedulerConfig)
from repro.kvcache.paged_decode import PagedDecodeCache

impl = sys.argv[1]
cfg = get_smoke_config("gemma3-1b")
params = M.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(7)
P = rng.integers(1, cfg.vocab_size, 24).astype(np.int32)

# (a) prefix-hit decode token-identical to a cold run of the same prompt
be = EngineBackend(cfg, params, n_slots=1, max_len=64, prefix_cache=True,
                   page_size=8)
be._paged_cache = None  # force construction with chosen impl below
pc, radix = be._prefix_structures()
pc.impl = impl
outs = []
for epoch in range(2):
    r = Request(epoch, P.copy(), max_new_tokens=6)
    done = ContinuousBatchingScheduler(be, SchedulerConfig()).serve([r])
    outs.append(list(done[0].output))
st = be.prefix_stats
assert st["prefix_hits"] >= 1, st
assert outs[0] == outs[1], (impl, outs)
print(f"{impl}: warm==cold tokens OK {outs[0][:4]}...")

# (b) chunked prefill bitwise-equal to monolithic at bf16
last = {}
for chunk in (0, 7, 16):
    pc = PagedDecodeCache(cfg, 1, 64, page_size=8, impl=impl)
    last[chunk] = np.asarray(pc.prefill(params, P[None, :], chunk=chunk),
                             np.float32)
    pc.release()
    assert pc.pool.alloc.used_pages == 0
for chunk in (7, 16):
    assert (last[chunk] == last[0]).all(), (impl, chunk)
print(f"{impl}: chunked==monolithic bitwise OK")
"""


ENGINE_CHUNK_WORKER = r"""
import functools, sys
import jax, jax.numpy as jnp
jnp.bfloat16 = jnp.float32   # fp32 => losslessness must be (near-)exact
import repro.core.engine as E
from repro.configs.base import ModelConfig, Family
from repro.models import model as M

cfg = ModelConfig(name="d", family=Family.DENSE, n_layers=8, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16)
key = jax.random.PRNGKey(0)
mesh = jax.make_mesh((4, 2), ("data", "model"))
params = jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
    M.init_params(cfg, key))
toks = jax.random.randint(key, (1, 10), 1, cfg.vocab_size)

# reference: the classic dense prefill adopted via seed_cache
eng = E.InterleavedEngine(cfg, mesh, E.UniformPlan(4, 2, 1, 1), n_mb=1,
                          mb=1, max_len=32)
cache = jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
    M.init_cache(cfg, 1, 32))
ref_logits, cache = jax.jit(functools.partial(M.prefill, cfg))(
    params, toks, cache)
ref_last = ref_logits[:, -1].astype(jnp.float32)

# partial-context prefill rounds through the pipeline itself
state = eng.init_state(params)
lg, state = eng.prefill_partial(state, toks, chunk=4)
got_last = lg[:, -1].astype(jnp.float32)
err = float(jnp.abs(got_last[:, :cfg.vocab_size]
                    - ref_last[:, :cfg.vocab_size]).max())
pos = int(jax.device_get(state["glob"]["pos"]))
print(f"prefill_partial: pos={pos} worst={err:.2e}")
ok = err < 5e-4 and pos == 10

# the built cache must decode equivalently to the seeded one
tok = jnp.argmax(ref_last[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
seeded = eng.seed_cache(eng.init_state(params), cache)
for step in range(3):
    lg_a, state = eng.decode_step(state, tok)
    lg_b, seeded = eng.decode_step(seeded, tok)
    err = float(jnp.abs(lg_a.astype(jnp.float32)
                        - lg_b.astype(jnp.float32)).max())
    print(f"decode step {step}: worst={err:.2e}")
    ok = ok and err < 5e-4
    tok = jnp.argmax(lg_b[:, :cfg.vocab_size].astype(jnp.float32),
                     -1)[:, None].astype(jnp.int32)
sys.exit(0 if ok else 1)
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_engine_prefill_partial_matches_dense_prefill(run_worker):
    """Partial-context prefill rounds through the interleaved pipeline
    (chunked verify steps) build the same cache the classic dense
    prefill + seed_cache adoption does: same last-position logits, same
    subsequent decode."""
    r = run_worker(ENGINE_CHUNK_WORKER)
    assert r.returncode == 0


@pytest.mark.slow
@pytest.mark.subprocess
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_engine_prefix_hit_lossless_and_chunk_bitwise(impl, run_worker):
    """The §12 losslessness contract on real KV: a prefix-hit decode emits
    token-identical output to a cold run of the same prompt, and chunked
    prefill is bitwise-equal to monolithic (bf16), for both the blocked
    jnp reference and the Pallas kernel (interpret on CPU).
    (devices=None: this worker needs the real 1-device CPU.)"""
    r = run_worker(PREFIX_LOSSLESS_WORKER, impl, devices=None)
    assert r.returncode == 0
