"""Mamba-style selective SSM scan for TPU (Pallas) — hymba's SSM heads.

    h_t = exp(A·dt_t) ⊙ h_{t-1} + (dt_t · B_t) ⊗ x_t      h: (N, dh)
    y_t = C_t · h_t

TPU adaptation (vs. the CUDA selective-scan): the per-(batch, head) state
matrix h (N × dh, fp32) lives in VMEM scratch across the whole sequence —
grid (B, H, n_time_blocks) with the time dimension sequential, identical
in structure to the RWKV6 WKV kernel (the two recurrences differ only in
how the rank-1 update and the decay are parameterized). Per step the work
is a rank-1 outer product + an N-row reduction: VPU work on (N, dh) tiles.

Padding contract (ops.py): time padded with dt = 0 (decay = exp(0) = 1 and
update = 0 — identity steps); dh lane-padded with x = 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, s0_ref,   # in
                y_ref, sT_ref,                                # out
                state_ref,                                    # scratch
                *, block_t: int):
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _load():
        state_ref[...] = s0_ref[0, 0]

    a = a_ref[0]                                  # (1,) this head's A (<0)
    x = x_ref[0, 0].astype(jnp.float32)           # (block_t, dh)
    dt = dt_ref[0, 0].astype(jnp.float32)         # (block_t, 1)
    bmat = b_ref[0].astype(jnp.float32)           # (block_t, N)
    cmat = c_ref[0].astype(jnp.float32)           # (block_t, N)

    def step(t, h):
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)        # (1, dh)
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)      # (1, 1)
        b_t = jax.lax.dynamic_slice_in_dim(bmat, t, 1, 0)     # (1, N)
        c_t = jax.lax.dynamic_slice_in_dim(cmat, t, 1, 0)     # (1, N)
        decay = jnp.exp(a[0] * dt_t[0, 0])
        h = decay * h + (dt_t[0, 0] * b_t.T) * x_t            # (N, dh)
        y = c_t @ h                                           # (1, dh)
        # int dims spelled as ds(0, 1): bare python ints in a store index
        # tuple break old Pallas (NDIndexer expects Slice/array indices)
        pl.store(y_ref, (pl.ds(0, 1), pl.ds(0, 1), pl.ds(t, 1), slice(None)),
                 y[None, None].astype(y_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, block_t, step, state_ref[...])
    state_ref[...] = h

    @pl.when(it == nt - 1)
    def _emit():
        sT_ref[0, 0] = h


def ssm_scan_kernel(x, dt, b, c, a, s0, *, block_t: int = 256,
                    interpret: bool = False):
    """x: (B, H, S, dh); dt: (B, H, S, 1) fp32; b, c: (B, S, N) fp32
    (shared across heads); a: (H, 1) fp32 negative; s0: (B, H, N, dh) fp32.
    S % block_t == 0. Returns (y (B, H, S, dh) fp32, sT (B, H, N, dh))."""
    B, H, S, dh = x.shape
    N = b.shape[-1]
    block_t = min(block_t, S)
    grid = (B, H, S // block_t)

    t_spec = pl.BlockSpec((1, 1, block_t, dh), lambda bb, h, it: (bb, h, it, 0))
    dt_spec = pl.BlockSpec((1, 1, block_t, 1), lambda bb, h, it: (bb, h, it, 0))
    bc_spec = pl.BlockSpec((1, block_t, N), lambda bb, h, it: (bb, it, 0))
    s_spec = pl.BlockSpec((1, 1, N, dh), lambda bb, h, it: (bb, h, 0, 0))

    return pl.pallas_call(
        functools.partial(_ssm_kernel, block_t=block_t),
        grid=grid,
        in_specs=[t_spec, dt_spec, bc_spec, bc_spec,
                  pl.BlockSpec((1, 1), lambda bb, h, it: (h, 0)),
                  s_spec],
        out_specs=[t_spec, s_spec],
        out_shape=[jax.ShapeDtypeStruct((B, H, S, dh), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, N, dh), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((N, dh), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a, s0)
