"""Roofline term derivation (EXPERIMENTS.md §Roofline).

Two complementary sources, cross-checked:

1. **Analytic workload model** — FLOPs / HBM bytes / wire bytes per step
   from the config + input shape + mesh + engine plan. Primary numbers for
   the roofline table: XLA's `cost_analysis()` visits `while` bodies once
   (verified experimentally — see EXPERIMENTS.md §Dry-run), so raw HLO
   counts understate scanned work by ~L×.

2. **HLO collective inventory** — every collective op parsed out of
   `compiled.as_text()`, multiplied by its enclosing while-loop's trip
   count (extracted from the loop condition). This grounds the analytic
   wire-byte model in the actually-compiled program and catches GSPMD
   surprises (redundant all-gathers, accidental replication).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI with ~4 usable links per chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict

from repro.configs.base import AttnKind, InputShape, ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9
ICI_LINKS = 4
DTYPE = 2


# ============================================================================
# analytic workload model
# ============================================================================
@dataclasses.dataclass
class Terms:
    flops: float                # global FLOPs per step
    hbm_bytes: float            # global HBM traffic per step
    wire_bytes_per_dev: float   # per-device ICI traffic per step
    n_devices: int

    @property
    def compute_s(self) -> float:
        return self.flops / (self.n_devices * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.n_devices * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_dev / (ICI_BW_PER_LINK * ICI_LINKS)

    @property
    def dominant(self) -> str:
        d = {"compute": self.compute_s, "memory": self.memory_s,
             "collective": self.collective_s}
        return max(d, key=d.get)

    def as_dict(self) -> Dict[str, Any]:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "wire_bytes_per_dev": self.wire_bytes_per_dev,
                "compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s,
                "dominant": self.dominant}


def _attn_span(cfg: ModelConfig, ctx: int, long_mode: bool) -> float:
    if cfg.attn_kind == AttnKind.NONE:
        return 0.0
    if cfg.attn_kind == AttnKind.SLIDING or \
            (cfg.attn_kind == AttnKind.LOCAL_GLOBAL and long_mode):
        return min(ctx, cfg.window_size)
    if cfg.attn_kind == AttnKind.LOCAL_GLOBAL:
        r = cfg.local_global_ratio
        return (r * min(ctx, cfg.window_size) + ctx) / (r + 1)
    return ctx


def train_terms(cfg: ModelConfig, shape: InputShape,
                mesh_shape: Dict[str, int], strategy: str = "tp") -> Terms:
    n_dev = 1
    for v in mesh_shape.values():
        n_dev *= v
    B, S = shape.global_batch, shape.seq_len
    tokens = B * S
    N = cfg.active_params()
    # 6ND dense/MoE-active + attention quadratic term (fwd 2x + bwd 4x,
    # causal halves the square) + remat recompute (~1 extra fwd = +2ND)
    span = _attn_span(cfg, S, False)
    attn = 6.0 * cfg.n_layers * B * S * span * 0.5 \
        * cfg.n_heads * (cfg.head_dim or 0) * 2
    flops = 6.0 * N * tokens + attn
    flops_remat = (2.0 * N * tokens + attn / 3.0)
    flops += flops_remat
    p_bytes = cfg.total_params() * DTYPE
    # fwd read + bwd read + grad write (bf16) + AdamW: read m,v,master +
    # write m,v,master,params (fp32 moments)
    hbm = 3 * p_bytes + (3 + 4) * cfg.total_params() * 4
    # activations: remat stores layer-boundary carries, recompute re-reads
    hbm += 4.0 * tokens * cfg.d_model * DTYPE * cfg.n_layers
    # wire: grad all-reduce over (pod, data) = 2 x sharded-param bytes;
    # per-layer activation collectives for tensor parallel: 2 ar of (B,S,D)
    # per layer forward + backward
    data_par = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    model_par = mesh_shape.get("model", 1)
    wire = 0.0
    if strategy == "dp":
        # weights replicated: grad all-reduce over all n_dev chips; no
        # per-layer tensor-parallel traffic at all
        wire += 2.0 * p_bytes * (n_dev - 1) / n_dev
    else:
        if data_par > 1:
            wire += 2.0 * p_bytes / model_par * (data_par - 1) / data_par
        if model_par > 1:
            act = tokens / data_par * cfg.d_model * DTYPE
            wire += cfg.n_layers * 2 * 3 * act * 2 * (model_par - 1) \
                / model_par
    return Terms(flops, hbm, wire, n_dev)


def prefill_terms(cfg: ModelConfig, shape: InputShape,
                  mesh_shape: Dict[str, int]) -> Terms:
    n_dev = 1
    for v in mesh_shape.values():
        n_dev *= v
    B, S = shape.global_batch, shape.seq_len
    tokens = B * S
    N = cfg.active_params()
    span = _attn_span(cfg, S, False)
    attn = 2.0 * cfg.n_layers * B * S * span * 0.5 \
        * cfg.n_heads * (cfg.head_dim or 0) * 2
    flops = 2.0 * N * tokens + attn
    p_bytes = cfg.total_params() * DTYPE
    kv_write = cfg.n_layers * tokens * 2 * cfg.n_kv_heads \
        * (cfg.head_dim or 0) * DTYPE
    hbm = p_bytes + kv_write + 2.0 * tokens * cfg.d_model * DTYPE \
        * cfg.n_layers
    data_par = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    model_par = mesh_shape.get("model", 1)
    wire = 0.0
    if model_par > 1:
        act = tokens / data_par * cfg.d_model * DTYPE
        wire += cfg.n_layers * 2 * act * 2 * (model_par - 1) / model_par
    return Terms(flops, hbm, wire, n_dev)


def decode_terms(cfg: ModelConfig, shape: InputShape,
                 mesh_shape: Dict[str, int], *, n_seg: int, k_res: int,
                 k_off: int, n_mb: int, mb: int,
                 fetch_mode: str = "step",
                 long_mode: bool = False) -> Terms:
    """LIME engine serve_step: one token for `n_mb x mb` sequences.

    fetch_mode mirrors the engine schedule: 'slot' re-fetches the active
    chunk's streamed layers every pipeline slot (paper-literal per-segment
    streaming, n_slots fetches); 'step' restores each stage's streamed
    layers once per decode step (n_seg slabs) — the §Perf optimized path.
    """
    n_dev = 1
    for v in mesh_shape.values():
        n_dev *= v
    n_stage = mesh_shape.get("data", 1)
    model_par = mesh_shape.get("model", 1)
    pod = mesh_shape.get("pod", 1)
    B = shape.global_batch
    ctx = shape.seq_len
    N = cfg.active_params()
    k = k_res + k_off
    n_chunks = n_seg * n_stage
    n_slots = n_chunks + n_mb - 1
    span = _attn_span(cfg, ctx, long_mode)

    flops = 2.0 * N * B
    flops += 2.0 * cfg.n_layers * B * span * cfg.n_heads \
        * (cfg.head_dim or 0) * 2
    # bubble waste: invalid slots still compute (masked commit)
    occupancy = (n_chunks * n_mb) / (n_slots * n_stage)
    flops = flops / max(occupancy, 1e-6) * 1.0

    l_bytes = cfg.layer_params() * DTYPE
    kv_read = cfg.n_layers * B * span * 2 * cfg.n_kv_heads \
        * (cfg.head_dim or 0) * DTYPE
    # weights touched once per micro-batch group per chunk
    w_traffic = cfg.n_layers * l_bytes * max(n_mb // n_stage, 1)
    hbm = w_traffic + kv_read * 1.0 + B * cfg.d_model * DTYPE * cfg.n_layers
    # streamed weights also land in HBM on the consuming stage
    fetches = {"slot": n_slots, "chunk": n_chunks, "step": n_seg}[fetch_mode]
    stream_bytes_dev = k_off * l_bytes / model_par * (n_stage - 1) / n_stage
    hbm += stream_bytes_dev * fetches * n_stage

    wire = stream_bytes_dev * fetches                 # all_to_all, per dev
    wire += n_slots * mb * cfg.d_model * DTYPE        # ppermute ring
    PV = ((cfg.vocab_size + 255) // 256) * 256
    wire += 2.0 * n_mb * mb * PV * 4 / model_par      # logits psum
    if model_par > 1:                                  # TP activation ar
        wire += cfg.n_layers / n_stage * 2 * mb * cfg.d_model * DTYPE \
            * 2 * (model_par - 1) / model_par * n_mb
    return Terms(flops, hbm, wire, n_dev)


# ============================================================================
# HLO collective inventory with while-trip multiplication
# ============================================================================
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "f64": 8, "s64": 8}


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[m.group(1)]
    return total


def _split_computations(hlo: str) -> Dict[str, str]:
    comps: Dict[str, str] = {}
    cur = None
    buf: list = []
    for line in hlo.splitlines():
        m = re.match(r"^(%?[\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$", line) \
            or re.match(r"^ENTRY\s+(%?[\w\.\-]+)", line)
        if "{" in line and ("->" in line or line.startswith("ENTRY")):
            if cur:
                comps[cur] = "\n".join(buf)
            name = line.split("(")[0].strip().lstrip("%")
            name = name.replace("ENTRY", "").strip().lstrip("%")
            cur = name
            buf = [line]
        else:
            buf.append(line)
    if cur:
        comps[cur] = "\n".join(buf)
    return comps


def _trip_count(cond_body: str) -> int:
    """Heuristic: largest integer constant compared in the loop condition."""
    consts = [int(x) for x in re.findall(r"constant\((\d+)\)", cond_body)]
    return max(consts) if consts else 1


def collective_inventory(hlo: str) -> Dict[str, Any]:
    comps = _split_computations(hlo)
    # map body computation -> trip count via while ops
    trips: Dict[str, int] = {}
    for cname, text in comps.items():
        for m in re.finditer(
                r"while\([^)]*\),\s*condition=%?([\w\.\-]+),\s*"
                r"body=%?([\w\.\-]+)", text):
            cond, body = m.group(1), m.group(2)
            trips[body] = _trip_count(comps.get(cond, ""))

    # nested loops: body computations containing while ops multiply
    def effective_trip(cname: str, seen=()) -> int:
        t = trips.get(cname, 1)
        return t

    per_op = {c: 0.0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for cname, text in comps.items():
        mult = effective_trip(cname)
        # account nesting one level: if this comp is a body nested inside
        # another body, multiply (walk callers)
        for line in text.splitlines():
            ls = line.strip()
            for c in COLLECTIVES:
                if re.search(rf"= [^=]*\b{c}(-start)?\(", ls):
                    lhs = ls.split("=")[1]
                    lhs = lhs.split(c)[0]
                    per_op[c] += _shape_bytes(lhs) * mult
                    counts[c] += 1
                    break
    total = sum(per_op.values())
    return {"bytes": per_op, "counts": counts, "total_bytes": total,
            "loop_trips": trips}
