"""GQA attention: chunked (flash-style) prefill/train path + cached decode path.

The prefill path is chunked over query blocks with a ``lax.scan`` so the full
(S, S) score matrix is never materialized — mandatory for the 32k-prefill input
shape (a naive 32k x 32k score tensor would not fit HBM), and it keeps the HLO
size O(1) in sequence length. Each chunk sees its full key row, so a plain
(numerically stable) softmax suffices — no online rescaling needed here; the
Pallas kernels (kernels/flash_attention, kernels/decode_attention) implement the
true blocked online-softmax versions for TPU and are validated against this
reference logic.

Sliding-window masks are expressed with a *traced* window scalar so that
gemma3-style local:global stacks can scan one homogeneous layer body over a
per-layer window array.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.modules import apply_rope
from repro.models.spec import ParamSpec

NEG_INF = -2.0e30


def attention_specs(d_model: int, n_heads: int, n_kv_heads: int,
                    head_dim: int) -> dict:
    return {
        "wq": ParamSpec((d_model, n_heads, head_dim), ("embed", "heads", None)),
        "wk": ParamSpec((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", None)),
        "wo": ParamSpec((n_heads, head_dim, d_model), ("heads", None, "embed")),
    }


def _sdpa_chunk(q, k, v, mask, scale):
    """q: (B,C,KV,G,dh); k,v: (B,S,KV,dh); mask: (B?,1?,C,S) bool -> (B,C,KV,G,dh)."""
    scores = jnp.einsum("bckgd,bskd->bkgcs", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgcs,bskd->bckgd", probs, v)


def chunked_attention(q, k, v, *, causal: bool, window, q_offset=0,
                      chunk_size: int = 1024, kv_offset: int = 0):
    """Blocked attention.

    q: (B, Sq, H, dh)   k, v: (B, Skv, KVH, dh)
    window: traced or static int — keys j are visible to query i iff
            (not causal or j <= i) and (i - j < window).
    """
    B, Sq, H, dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = dh ** -0.5
    q = q.reshape(B, Sq, KV, G, dh)
    C = min(chunk_size, Sq)
    if Sq % C:
        C = Sq  # smoke-test sizes: single chunk
    n_chunks = Sq // C
    j = kv_offset + jnp.arange(Skv)

    def one_chunk(carry, qc_and_idx):
        qc, c_idx = qc_and_idx
        i = q_offset + c_idx * C + jnp.arange(C)
        mask = jnp.ones((C, Skv), bool)
        if causal:
            mask &= j[None, :] <= i[:, None]
        if window is not None:
            mask &= (i[:, None] - j[None, :]) < window
        out = _sdpa_chunk(qc, k, v, mask[None], scale)
        return carry, out

    if n_chunks == 1:
        _, out = one_chunk(None, (q, jnp.int32(0)))
    else:
        qs = q.reshape(n_chunks, B, C, KV, G, dh)
        _, out = jax.lax.scan(one_chunk, None,
                              (qs, jnp.arange(n_chunks, dtype=jnp.int32)))
        out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, KV, G, dh)
    return out.reshape(B, Sq, H, dh)


def mq_decode_attention_ref(q, k_cache, v_cache, pos_ids, pos, *, window):
    """q_len>1 decode attention against a (possibly ring-buffer) KV cache:
    the multi-query generalization of `decode_attention_ref` used by
    speculative-decoding verification (DESIGN.md §11).

    q: (B, q_len, H, dh) — query i sits at absolute position pos + i;
    k_cache/v_cache: (B, S_c, KV, dh) with the q_len new K/V already
    written; pos_ids: (S_c,) absolute position per slot (-1 = empty);
    pos: scalar position of query 0. Returns (B, q_len, H, dh).
    """
    B, Q, H, dh = q.shape
    S_c, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = dh ** -0.5
    qg = q.reshape(B, Q, KV, G, dh)
    scores = jnp.einsum("bqkgd,bskd->bqkgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    qpos = pos + jnp.arange(Q)                     # (Q,)
    valid = (pos_ids[None, :] >= 0) \
        & (pos_ids[None, :] <= qpos[:, None])      # (Q, S_c)
    if window is not None:
        valid &= (qpos[:, None] - pos_ids[None, :]) < window
    scores = jnp.where(valid[None, :, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bqkgs,bskd->bqkgd", probs, v_cache)
    return out.reshape(B, Q, H, dh)


def decode_attention_ref(q, k_cache, v_cache, pos_ids, pos, *, window):
    """One-token attention against a (possibly ring-buffer) KV cache.

    q: (B, 1, H, dh); k_cache/v_cache: (B, S_c, KV, dh);
    pos_ids: (S_c,) absolute position stored in each slot (-1 = empty);
    pos: scalar current position. Returns (B, 1, H, dh).
    """
    B, _, H, dh = q.shape
    S_c, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = dh ** -0.5
    qg = q.reshape(B, KV, G, dh)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    valid = (pos_ids >= 0) & (pos_ids <= pos)
    if window is not None:
        valid &= (pos - pos_ids) < window
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache)
    return out.reshape(B, 1, H, dh)


# ----------------------------------------------------------------------------
# Full attention block (projections + rope + sdpa)
# ----------------------------------------------------------------------------
def attn_forward(params, x, *, rope_theta, causal=True, window=None,
                 q_offset=0, positions=None, kv=None, impl: str = "ref"):
    """Sequence attention (train / prefill). Returns (out, (k, v)) where k, v
    are the rope'd keys/values for KV-cache seeding.

    kv: optional (k_src, v_src) hidden states for cross-attention.
    """
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    src = x if kv is None else kv[0]
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src if kv is None else kv[1], params["wv"])
    if kv is None:  # self-attention: rotary on q and k
        if positions is None:
            positions = q_offset + jnp.arange(S)[None, :]
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, q_offset * 0 + (positions if kv is None else positions),
                       rope_theta)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    else:
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                q_offset=0 if kv is None else q_offset)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, (k, v)


def attn_decode(params, x, cache_k, cache_v, pos_ids, pos, slot, *, rope_theta,
                window=None, impl: str = "ref"):
    """Single-token decode. x: (B, 1, D); slot: cache index to write (the model
    computes it once — ring or linear — so layers can be scanned uniformly);
    pos_ids: (S_c,) already updated with `pos` at `slot`.
    Returns (out, new_cache_k, new_cache_v)."""
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    posb = jnp.full((B, 1), pos)
    q = apply_rope(q, posb, rope_theta)
    k = apply_rope(k, posb, rope_theta)
    ck = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    if impl == "pallas":
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.decode_attention(q, ck, cv, pos_ids, pos, window=window)
    else:
        out = decode_attention_ref(q, ck, cv, pos_ids, pos, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, ck, cv


def attn_decode_multi(params, x, cache_k, cache_v, pos_ids, pos, slots, *,
                      rope_theta, window=None, impl: str = "ref"):
    """q_len-token verification decode (speculative decoding, DESIGN.md
    §11). x: (B, q_len, D); slots: (q_len,) cache indices receiving the
    new K/V (position pos + i lands at slots[i]); pos_ids: (S_c,) already
    updated with pos + i at slots[i]. All q_len K/V are written first, so
    the queries attend to each other through the cache; causality between
    them is the per-query validity mask (pos_ids <= pos + i) — exactly the
    arithmetic sequential `attn_decode` steps would have produced.
    Returns (out (B, q_len, D), new_cache_k, new_cache_v)."""
    B, Q, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    posb = pos + jnp.broadcast_to(jnp.arange(Q), (B, Q))
    q = apply_rope(q, posb, rope_theta)
    k = apply_rope(k, posb, rope_theta)
    # contiguous write at slots[0] (dynamic_update_slice — the one update
    # op old XLA's partial-auto partitioner accepts inside the engine's
    # shard_map; Scatter/one-hot variants trip its manual-subgroup
    # check). Callers guarantee the verify window never wraps the ring:
    # the serving backend caps q_len so pos + q_len <= max_len.
    ck = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                      (0, slots[0], 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                      (0, slots[0], 0, 0))
    if impl == "pallas":
        from repro.kernels.decode_attention import multiquery as mq
        out = mq.mq_decode_attention(q, ck, cv, pos_ids, pos, window=window)
    else:
        out = mq_decode_attention_ref(q, ck, cv, pos_ids, pos,
                                      window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, ck, cv


def paged_attn_decode(params, x, k_pool, v_pool, page_ids, slot,
                      block_tables, ctx_lens, pos, *, rope_theta,
                      window=None, impl: str = "ref"):
    """Single-token decode against a *paged* KV pool (DESIGN.md §10).

    x: (B, 1, D); k_pool/v_pool: (P, page_size, KV, dh) shared physical
    pages; page_ids: (B,) physical page receiving this token; slot: scalar
    offset inside that page (all sequences share `pos`, so it is uniform);
    block_tables: (B, max_pages) int32 (-1 pads); ctx_lens: (B,) tokens
    live *including* this one. Returns (out, new_k_pool, new_v_pool)."""
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    posb = jnp.full((B, 1), pos)
    q = apply_rope(q, posb, rope_theta)
    k = apply_rope(k, posb, rope_theta)
    ck = k_pool.at[page_ids, slot].set(k[:, 0].astype(k_pool.dtype))
    cv = v_pool.at[page_ids, slot].set(v[:, 0].astype(v_pool.dtype))
    if impl == "pallas":
        from repro.kernels.decode_attention import paged as pg
        out = pg.paged_decode_attention(q, ck, cv, block_tables, ctx_lens,
                                        window=window)
    else:
        from repro.kernels.decode_attention.paged import \
            paged_decode_attention_ref
        out = paged_decode_attention_ref(q, ck, cv, block_tables, ctx_lens,
                                         window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, ck, cv


def paged_attn_decode_multi(params, x, k_pool, v_pool, page_ids, slots,
                            block_tables, ctx_lens, pos, *, rope_theta,
                            window=None, impl: str = "ref"):
    """q_len-token verification decode against a paged KV pool (DESIGN.md
    §11). x: (B, q_len, D); page_ids: (B, q_len) physical page per new
    token; slots: (q_len,) offsets inside those pages (shared `pos`
    convention, so uniform across the batch); ctx_lens: (B,) tokens live
    *including* the q_len new ones. Returns (out, new_k_pool, new_v_pool).
    """
    B, Q, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    posb = pos + jnp.broadcast_to(jnp.arange(Q), (B, Q))
    q = apply_rope(q, posb, rope_theta)
    k = apply_rope(k, posb, rope_theta)
    slot_b = jnp.broadcast_to(slots, (B, Q))
    ck = k_pool.at[page_ids, slot_b].set(k.astype(k_pool.dtype))
    cv = v_pool.at[page_ids, slot_b].set(v.astype(v_pool.dtype))
    from repro.kernels.decode_attention import multiquery as mq
    if impl == "pallas":
        out = mq.mq_paged_decode_attention(q, ck, cv, block_tables,
                                           ctx_lens, window=window)
    else:
        out = mq.mq_paged_decode_attention_ref(q, ck, cv, block_tables,
                                               ctx_lens, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, ck, cv


def cross_attn_decode(params, x, ck, cv, enc_len, impl: str = "ref"):
    """Decode-time cross attention against precomputed encoder K/V.
    ck, cv: (B, S_enc, KV, dh)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    pos_ids = jnp.arange(ck.shape[1])
    valid_to = jnp.asarray(enc_len)
    out = decode_attention_ref(q, ck, cv, jnp.where(pos_ids < valid_to, pos_ids, -1),
                               jnp.int32(2 ** 30), window=None)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])
