"""Model/architecture configuration system.

Every assigned architecture (and the paper's own three models) is expressed as a
:class:`ModelConfig`. Configs are *data*: they carry exact dimensions from the
source paper / model card (cited in each ``configs/<id>.py``) plus the knobs the
LIME scheduler needs (block memory proportions p_A / p_M are *derived*, not
hard-coded).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    ENCDEC = "encdec"   # audio / seq2seq backbone
    VLM = "vlm"         # decoder backbone consuming patch embeddings


class AttnKind(str, enum.Enum):
    FULL = "full"                 # full causal attention
    SLIDING = "sliding"           # sliding-window attention
    LOCAL_GLOBAL = "local_global" # gemma3-style N local : 1 global
    NONE = "none"                 # attention-free (SSM)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    # --- attention structure ---
    attn_kind: AttnKind = AttnKind.FULL
    window_size: int = 1024                 # for sliding / local layers
    local_global_ratio: int = 5             # gemma3: 5 local : 1 global
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: Optional[int] = None          # per-expert d_ff (fine-grained MoE)
    first_dense_layers: int = 0             # deepseek-moe: layer 0 dense
    router_aux_coef: float = 0.01
    # --- SSM / hybrid ---
    ssm_state_size: int = 0
    ssm_heads: int = 0                      # hymba: # mamba heads in parallel
    # --- enc-dec ---
    n_encoder_layers: int = 0
    # --- modality frontend stub ---
    frontend_tokens: int = 0                # patch/frame embeddings prepended
    # --- misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    parallel_block: bool = False            # stablelm-2 style parallel attn+MLP
    max_seq_len: int = 524_288
    source: str = ""                        # citation from assignment

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        assert self.n_kv_heads == 0 or self.n_heads % max(self.n_kv_heads, 1) == 0, (
            f"{self.name}: n_heads={self.n_heads} not divisible by kv={self.n_kv_heads}")

    # ------------------------------------------------------------------
    # Derived quantities used by the LIME cost model (§IV-B, Tab. I).
    # ------------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.attn_kind == AttnKind.NONE

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def attn_params_per_layer(self) -> int:
        """Parameter count of the MHA block (q,k,v,o projections)."""
        if self.is_attention_free:
            # RWKV time-mix block plays the MHA role: r,k,v,g,o + decay.
            return 5 * self.d_model * self.d_model + 2 * self.d_model
        hd = self.head_dim
        q = self.d_model * self.n_heads * hd
        kv = 2 * self.d_model * self.n_kv_heads * hd
        o = self.n_heads * hd * self.d_model
        if self.family == Family.HYBRID and self.ssm_heads > 0:
            # hymba: parallel SSM heads share the block (in/out proj + ssm params)
            o += 2 * self.d_model * self.d_model + self.ssm_heads * self.ssm_state_size * 2
        if self.family == Family.ENCDEC:
            o += q + kv + o  # cross-attention block in decoder layers
        return q + kv + o

    def mlp_params_per_layer(self, layer_idx: int = 1) -> int:
        """Parameter count of the MLP / expert block of one layer."""
        if self.is_moe and layer_idx >= self.first_dense_layers:
            dff = self.moe_d_ff or self.d_ff
            routed = self.n_experts * 3 * self.d_model * dff
            shared = self.n_shared_experts * 3 * self.d_model * dff
            router = self.d_model * self.n_experts
            return routed + shared + router
        return 3 * self.d_model * self.d_ff  # gated (silu) MLP: up, gate, down

    def layer_params(self, layer_idx: int = 1) -> int:
        return (self.attn_params_per_layer() + self.mlp_params_per_layer(layer_idx)
                + 2 * self.d_model)  # two RMSNorm scales

    def total_params(self) -> int:
        body = sum(self.layer_params(i) for i in range(self.n_layers))
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        enc = 0
        if self.n_encoder_layers:
            enc_layer = (4 * self.d_model * self.d_model
                         + 3 * self.d_model * self.d_ff + 2 * self.d_model)
            enc = self.n_encoder_layers * enc_layer
        return body + emb + enc + self.d_model

    def active_params(self) -> int:
        """Activated parameters per token (= total for dense)."""
        if not self.is_moe:
            return self.total_params()
        dff = self.moe_d_ff or self.d_ff
        act_mlp = (self.top_k + self.n_shared_experts) * 3 * self.d_model * dff
        per_layer = self.attn_params_per_layer() + act_mlp + 2 * self.d_model
        dense_layers = self.first_dense_layers
        dense_part = dense_layers * (self.attn_params_per_layer()
                                     + 3 * self.d_model * self.d_ff)
        body = (self.n_layers - dense_layers) * per_layer + dense_part
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        return body + emb + self.d_model

    # LIME block-granularity proportions (Tab. I: p_A, p_M).
    def p_A(self, layer_idx: int = 1) -> float:
        a = self.attn_params_per_layer()
        return a / max(self.layer_params(layer_idx), 1)

    def p_M(self, layer_idx: int = 1) -> float:
        m = self.mlp_params_per_layer(layer_idx)
        return m / max(self.layer_params(layer_idx), 1)

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache bytes per token across all layers (cost-model `mem(token)`)."""
        if self.is_attention_free:
            return 0  # O(1) state, not per-token
        kv_layers = self.n_layers
        if self.attn_kind == AttnKind.LOCAL_GLOBAL:
            pass  # window caps length, not per-token width
        return kv_layers * 2 * self.n_kv_heads * self.head_dim * dtype_bytes

    def layer_bytes(self, dtype_bytes: int = 2, layer_idx: int = 1) -> int:
        return self.layer_params(layer_idx) * dtype_bytes

    def supports_long_context(self) -> bool:
        """True if decode KV state is sub-linear in context (long_500k eligible)."""
        return self.attn_kind in (AttnKind.NONE, AttnKind.SLIDING,
                                  AttnKind.LOCAL_GLOBAL)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized variant of the same family (2 layers, d_model<=512)."""
    small = dict(
        n_layers=2,
        d_model=min(cfg.d_model, 256),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)) if cfg.n_kv_heads else 0,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 1024),
        head_dim=64 if cfg.head_dim and cfg.head_dim > 64 else cfg.head_dim,
        max_seq_len=4096,
    )
    if cfg.is_moe:
        small.update(n_experts=min(cfg.n_experts, 4), top_k=min(cfg.top_k, 2),
                     n_shared_experts=min(cfg.n_shared_experts, 1),
                     moe_d_ff=min(cfg.moe_d_ff or cfg.d_ff, 128),
                     first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.ssm_state_size:
        small.update(ssm_state_size=min(cfg.ssm_state_size, 16))
    if cfg.ssm_heads:
        small.update(ssm_heads=min(cfg.ssm_heads, 2))
    if cfg.n_encoder_layers:
        small.update(n_encoder_layers=2)
    if cfg.frontend_tokens:
        small.update(frontend_tokens=min(cfg.frontend_tokens, 16))
    if cfg.attn_kind in (AttnKind.SLIDING, AttnKind.LOCAL_GLOBAL):
        small.update(window_size=min(cfg.window_size, 128))
    small.update(overrides)
    fixed = {f.name: getattr(cfg, f.name) for f in dataclasses.fields(cfg)}
    fixed.update(small)
    # keep head count consistent with kv heads
    if fixed["n_kv_heads"] and fixed["n_heads"] % fixed["n_kv_heads"]:
        fixed["n_heads"] = fixed["n_kv_heads"] * max(
            1, fixed["n_heads"] // fixed["n_kv_heads"])
    # hybrid blocks fuse equal-width attention/SSM head groups
    if fixed["ssm_heads"]:
        fixed["ssm_heads"] = fixed["n_heads"]
    return ModelConfig(**fixed)
