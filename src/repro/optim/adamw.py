"""AdamW + schedules, pure-JAX pytrees (no optax dependency).

State is a pytree mirroring params, so ZeRO-1 sharding is just a tree of
NamedShardings over the `data` axis (launch/train.py builds those).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # () int32
    mu: Any                    # first moment (pytree, fp32)
    nu: Any                    # second moment (pytree, fp32)
    master: Any                # fp32 master weights (bf16 params would lose
                               # sub-ulp updates — standard mixed precision)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray]     # schedule: step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(jnp.int32(0), jax.tree.map(z, params),
                          jax.tree.map(z, params),
                          jax.tree.map(lambda p: p.astype(jnp.float32),
                                       params))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip:
            gnorm = global_norm(gf)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            gf = jax.tree.map(lambda g: g * scale, gf)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, gf)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, gf)
        c1 = 1 - self.b1 ** step.astype(jnp.float32)
        c2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(w, m, v):
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if w.ndim >= 2:                      # decay matrices only
                delta = delta + self.weight_decay * w
            return w - lr * delta

        master = jax.tree.map(upd, state.master, mu, nu)
        new_params = jax.tree.map(lambda w, p: w.astype(p.dtype),
                                  master, params)
        return new_params, AdamWState(step, mu, nu, master)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return f


def constant_schedule(lr: float):
    return lambda step: jnp.float32(lr)
