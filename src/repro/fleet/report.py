"""FleetReport: exact fleet-level aggregation of per-replica serving runs
(DESIGN.md §16).

A ServingReport stores percentiles, not samples — averaging replica
percentiles would be wrong (the p99 of a union is not the mean of p99s).
So the fleet aggregates one level down, where exactness is possible:

  latency metrics   the pooled raw Request records from every replica run
                    through the same `summarize()` a single pipeline uses
  counters/gauges/  `MetricsRegistry.merge` — counters sum, gauges max,
  histograms        histograms concatenate raw samples (merged
                    percentiles == pooled-sample percentiles, asserted
                    in tests/test_fleet.py)

The per-replica ServingReports are kept alongside the aggregate: a fleet
whose aggregate looks healthy can still hide one replica eating all the
queueing — the per-replica breakdown is where that shows.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

from repro.obs.metrics import MetricsRegistry
from repro.serving.metrics import SCHEMA_VERSION, ServingReport, summarize
from repro.serving.scheduler import Request


@dataclasses.dataclass
class FleetReport:
    pattern: str
    backend: str
    n_replicas: int                    # ever members (incl. retired)
    router_policy: str
    aggregate: ServingReport           # over the pooled request records
    replicas: Dict[str, ServingReport]  # per-replica breakdown
    router: Dict[str, float]           # FleetRouter.stats
    membership: Dict[str, dict]        # per-replica routed/joined/retired
    schema_version: int = SCHEMA_VERSION

    def to_dict(self) -> dict:
        return {
            "schema_version": self.schema_version,
            "pattern": self.pattern,
            "backend": self.backend,
            "n_replicas": self.n_replicas,
            "router_policy": self.router_policy,
            "aggregate": self.aggregate.to_dict(),
            "replicas": {k: v.to_dict() for k, v in self.replicas.items()},
            "router": dict(self.router),
            "membership": {k: dict(v) for k, v in self.membership.items()},
        }

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)


@dataclasses.dataclass
class FleetResult:
    """What Fleet.run returns: the raw material a FleetReport is built
    from (pooled + partitioned records, final replica/router state)."""
    requests: List[Request]            # every record, all replicas + shed
    per_replica: Dict[str, List[Request]]
    replicas: List                     # final Replica objects
    router: object                     # the FleetRouter (stats + config)
    shed: List[Request]                # router-level rejections

    def report(self, *, pattern: str = "", backend: str = "") -> FleetReport:
        merged = MetricsRegistry()
        per: Dict[str, ServingReport] = {}
        membership: Dict[str, dict] = {}
        for rep in self.replicas:
            per[rep.name] = summarize(self.per_replica.get(rep.name, []),
                                      pattern=pattern, backend=backend,
                                      stats=rep.sched.metrics)
            merged.merge(rep.sched.metrics)
            membership[rep.name] = {
                "routed": rep.routed,
                "joined_s": rep.joined_s,
                "retired_s": rep.retired_s,
                "draining": rep.draining,
                "live": rep.live,
                "health": rep.health(),
            }
            slo = getattr(rep.sched, "slo", None)
            if slo is not None:
                membership[rep.name]["slo"] = slo.snapshot(rep.now())
        aggregate = summarize(self.requests, pattern=pattern,
                              backend=backend, stats=merged)
        return FleetReport(pattern=pattern, backend=backend,
                           n_replicas=len(self.replicas),
                           router_policy=self.router.config.policy,
                           aggregate=aggregate, replicas=per,
                           router=dict(self.router.stats),
                           membership=membership)
