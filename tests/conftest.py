"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (1-device) CPU; distributed engine tests re-exec themselves in
a subprocess with a forced device count (see test_engine.py)."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def assert_finite(x, msg=""):
    assert bool(jnp.isfinite(jnp.asarray(x, jnp.float32)).all()), msg
