"""Trace exporters: Chrome trace-event JSON (Perfetto) + JSONL
(DESIGN.md §15).

Two formats, one source of truth (the Tracer's ring):

  Chrome trace-event JSON   load in Perfetto (ui.perfetto.dev) or
                            chrome://tracing. Tracks map to pid/tid pairs:
                            pid 0 "serving" (scheduler, kv, prefix,
                            engine), pid 1 "fleet" (one tid per device /
                            loader), pid 2 "requests" (one tid per
                            request) — the per-request lifecycle lanes the
                            issue-motivating "where did the p99 TTFT go"
                            question needs. Timestamps convert s -> µs
                            (the format's unit).
  JSONL                     one JSON object per line, first line a header
                            {"schema": "lime-trace", "version": N} —
                            append-friendly, streams through jq/pandas for
                            post-hoc analysis, round-trips losslessly
                            (read_jsonl).
"""
from __future__ import annotations

import json
from typing import Dict, List, Tuple

from repro.obs.trace import (EVT_ARGS, EVT_DUR, EVT_NAME, EVT_PH, EVT_TRACK,
                             EVT_TS, Event, Tracer)

JSONL_SCHEMA = "lime-trace"
JSONL_VERSION = 1

_PHASES = ("i", "X", "B", "E", "C", "M")


# pids 0-3 are the single-pipeline process groups; namespaced replica
# tracks ("r<N>:...", Tracer.namespace) land at _REPLICA_PID_BASE + N so
# Perfetto renders one process group per fleet replica
_PID_NAMES = {0: "serving", 1: "fleet", 2: "requests", 3: "router"}
_REPLICA_PID_BASE = 10


def _replica_of(track: str):
    """'r3:sched' -> 3; None for un-namespaced tracks ('req:5' included)."""
    ns, sep, rest = track.partition(":")
    if sep and rest and len(ns) > 1 and ns[0] == "r" and ns[1:].isdigit():
        return int(ns[1:])
    return None


def _track_pids(tracks) -> Dict[str, Tuple[int, int]]:
    """Stable track -> (pid, tid) assignment. Request tracks get their
    own process so Perfetto renders one lane per request; device tracks
    one lane per device/loader; every replica-namespaced track (rN:...)
    lands in that replica's own process group; router events get their
    own fleet-level process."""
    out: Dict[str, Tuple[int, int]] = {}
    next_tid: Dict[int, int] = {}
    for tr in sorted(set(tracks)):
        rep = _replica_of(tr)
        if rep is not None:
            pid = _REPLICA_PID_BASE + rep
        elif tr == "router" or tr.startswith("fleet"):
            pid = 3
        elif tr.startswith("req:"):
            pid = 2
        elif tr.startswith("dev:"):
            pid = 1
        else:
            pid = 0
        out[tr] = (pid, next_tid.get(pid, 0))
        next_tid[pid] = next_tid.get(pid, 0) + 1
    return out


def _pid_names(pids: Dict[str, Tuple[int, int]]) -> Dict[int, str]:
    names = dict(_PID_NAMES)
    for pid, _ in pids.values():
        if pid >= _REPLICA_PID_BASE:
            names[pid] = f"replica r{pid - _REPLICA_PID_BASE}"
    return names


def to_chrome(tracer: Tracer) -> dict:
    """The Chrome trace-event representation (JSON object format)."""
    events = tracer.events()
    pids = _track_pids([e[EVT_TRACK] for e in events])
    out: List[dict] = []
    for pid, pname in sorted(_pid_names(pids).items()):
        out.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                    "args": {"name": pname}})
    for track, (pid, tid) in pids.items():
        out.append({"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                    "args": {"name": track}})
        # request lanes in rid order, devices in index order
        out.append({"name": "thread_sort_index", "ph": "M", "pid": pid,
                    "tid": tid, "args": {"sort_index": tid}})
    for e in events:
        pid, tid = pids[e[EVT_TRACK]]
        rec = {"name": e[EVT_NAME], "ph": e[EVT_PH], "pid": pid, "tid": tid,
               "ts": e[EVT_TS] * 1e6}
        if e[EVT_PH] == "X":
            rec["dur"] = e[EVT_DUR] * 1e6
        if e[EVT_PH] == "i":
            rec["s"] = "t"                       # thread-scoped instant
        if e[EVT_ARGS]:
            rec["args"] = dict(e[EVT_ARGS])
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"schema": JSONL_SCHEMA, "version": JSONL_VERSION,
                          "dropped_events": tracer.dropped}}


def export_chrome(tracer: Tracer, path: str) -> int:
    """Write Perfetto-loadable Chrome trace JSON; returns events written."""
    doc = to_chrome(tracer)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(doc["traceEvents"])


def export_jsonl(tracer: Tracer, path: str, append: bool = False) -> int:
    """Write (or append) the buffer as JSONL; returns events written."""
    mode = "a" if append else "w"
    events = tracer.events()
    with open(path, mode) as f:
        if not append:
            f.write(json.dumps({"schema": JSONL_SCHEMA,
                                "version": JSONL_VERSION}) + "\n")
        for e in events:
            f.write(json.dumps({"name": e[EVT_NAME], "ph": e[EVT_PH],
                                "ts": e[EVT_TS], "dur": e[EVT_DUR],
                                "track": e[EVT_TRACK],
                                "args": e[EVT_ARGS]}) + "\n")
    return len(events)


def read_jsonl(path: str) -> Tuple[dict, List[Event]]:
    """Load a JSONL trace back into (header, event tuples) — the inverse
    of export_jsonl, so analysis code works on the in-memory layout."""
    header: dict = {}
    events: List[Event] = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if i == 0 and "schema" in rec:
                header = rec
                continue
            events.append((rec["name"], rec["ph"], rec["ts"], rec["dur"],
                           rec["track"], rec["args"]))
    return header, events


def validate_chrome(doc: dict) -> List[str]:
    """Check a Chrome trace-event document against the format's schema
    (the subset Perfetto requires). Returns a list of problems — empty
    means valid. Used by tests and the CI trace smoke."""
    problems: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing top-level 'traceEvents'"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not a list"]
    open_spans: Dict[Tuple[int, int], List[str]] = {}
    for i, e in enumerate(events):
        where = f"event {i}"
        if not isinstance(e, dict):
            problems.append(f"{where}: not an object")
            continue
        for key in ("name", "ph", "pid", "tid"):
            if key not in e:
                problems.append(f"{where}: missing '{key}'")
        ph = e.get("ph")
        if ph not in _PHASES:
            problems.append(f"{where}: unknown phase {ph!r}")
            continue
        if ph != "M":
            if not isinstance(e.get("ts"), (int, float)):
                problems.append(f"{where}: non-numeric ts")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X event needs dur >= 0")
        key = (e.get("pid"), e.get("tid"))
        if ph == "B":
            open_spans.setdefault(key, []).append(e.get("name", ""))
        elif ph == "E":
            stack = open_spans.get(key, [])
            if not stack:
                problems.append(f"{where}: E without matching B on {key}")
            else:
                stack.pop()
    for key, stack in open_spans.items():
        if stack:
            problems.append(f"unclosed B events on track {key}: {stack}")
    return problems


def validate_chrome_file(path: str) -> List[str]:
    with open(path) as f:
        return validate_chrome(json.load(f))
