"""Resident-tier self-speculative drafting (DESIGN.md §14).

LIME's offload split leaves a *resident* tier permanently in device HBM
while the streamed tier pays a weight-fetch round per decoded token. That
split is a free draft model: run a truncated forward pass through only the
resident layers (skip every streamed layer; apply the final norm + LM head
as an early-exit head) and the proposal costs zero extra weight HBM and no
streaming round. Verification still goes through the full interleaved
pipeline with the rejection sampler, so output stays lossless — the draft
only sets the acceptance rate.

Two pieces live here:

  ResidentDraft    host-side DraftProvider over a truncated layer stack
                   (the single-device analogue of the engine's
                   ``draft_step``, sharing embed/final-norm/unembed with
                   the target as the early-exit head). The engine path
                   drafts on-device instead (``InterleavedEngine.
                   draft_step``) and never builds this class.
  DepthController  retier-adaptive draft depth: per-rung acceptance-rate
                   EMA, where a rung is the number of currently demoted
                   layers. Demotions thin the draft and shrink k;
                   promotions restore it. k = round(a/(1-a)) clipped to
                   [k_min, spec.k] — the expected accepted run of a
                   geometric(a) acceptance stream, never exceeding the
                   scheduler's per-round token reservation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.specdec.draft import SmallModelDraft


def default_resident_ids(cfg, n: Optional[int] = None) -> List[int]:
    """First-n layer ids for an engine-less resident draft (the bottom of
    the stack is what allocate() keeps resident in a uniform plan)."""
    if n is None:
        n = max(1, cfg.n_layers // 2)
    return list(range(min(max(int(n), 1), cfg.n_layers)))


def truncate_stack(cfg, params, resident_ids: Sequence[int]):
    """(cfg, params) restricted to ``resident_ids`` of the stacked layer
    pytree; embeddings, final norm and LM head are shared (early exit)."""
    import jax
    import jax.numpy as jnp
    ids = sorted({int(i) for i in resident_ids})
    if not ids:
        raise ValueError("resident draft needs at least one resident layer")
    if any(i < 0 or i >= cfg.n_layers for i in ids):
        raise ValueError(f"resident ids {ids} outside 0..{cfg.n_layers - 1}")
    if "dense_layers" in params:
        raise NotImplementedError(
            "resident draft supports homogeneous stacked layers only")
    idx = jnp.asarray(ids, jnp.int32)
    sub = {k: (jax.tree.map(lambda a: a[idx], v) if k == "layers" else v)
           for k, v in params.items()}
    sub_cfg = dataclasses.replace(cfg, n_layers=len(ids))
    return sub_cfg, sub


class ResidentDraft(SmallModelDraft):
    """DraftProvider running the target's own resident layers as the draft.

    Snapshot-and-advance semantics are inherited from SmallModelDraft (the
    truncated stack keeps its own committed-only cache; propose() decodes
    from an immutable snapshot). On top of that it tracks the committed
    token history so ``retier()`` can rebuild the truncated stack when the
    live tier boundary moves, replaying the history through the new stack.

    Window-pattern note: LOCAL_GLOBAL / sliding configs index their window
    pattern by position in the (truncated) stack, so a truncated model may
    see a different local/global mix than the same layers inside the full
    model. That only shifts draft quality — verification is lossless.
    """

    def __init__(self, cfg, params, resident_ids: Sequence[int], *,
                 max_len: int = 512, temperature: float = 0.0,
                 seed: int = 0):
        self._full_cfg = cfg
        self._full_params = params
        self.resident_ids = tuple(sorted({int(i) for i in resident_ids}))
        sub_cfg, sub_params = truncate_stack(cfg, params, self.resident_ids)
        super().__init__(sub_cfg, sub_params, max_len=max_len,
                         temperature=temperature, seed=seed)
        self._tokens: List[int] = []

    def reset(self, tokens) -> None:
        self._tokens = [int(t) for t in tokens]
        super().reset(tokens)

    def observe(self, tokens) -> None:
        self._tokens.extend(int(t) for t in tokens)
        super().observe(tokens)

    def retier(self, resident_ids: Sequence[int]) -> None:
        """The live tier boundary moved: rebuild the truncated stack and
        replay the committed history through it."""
        ids = tuple(sorted({int(i) for i in resident_ids}))
        if ids == self.resident_ids:
            return
        self.resident_ids = ids
        sub_cfg, sub_params = truncate_stack(self._full_cfg,
                                             self._full_params, ids)
        import functools

        import jax
        self.cfg = sub_cfg
        self.params = sub_params
        self._decode = jax.jit(functools.partial(self._M.decode_step,
                                                 sub_cfg))
        self._prefill = jax.jit(functools.partial(self._M.prefill, sub_cfg))
        if self._tokens:
            super().reset(self._tokens)


class DepthController:
    """Adapts draft depth k to the live tier boundary (DESIGN.md §14).

    State is an acceptance-rate EMA *per ladder rung* (rung = number of
    demoted layers): retier events switch rungs rather than polluting one
    global average, so a rung revisited after a promotion remembers what
    the draft was worth there. Unseen rungs start from a prior — callers
    pass ``acceptance x resident_fraction`` so a demotion immediately
    shrinks k instead of waiting for rejections to pile up."""

    def __init__(self, k_max: int, *, k_min: int = 1, decay: float = 0.7,
                 prior: float = 0.6):
        self.k_max = max(int(k_max), 1)
        self.k_min = min(max(int(k_min), 1), self.k_max)
        self.decay = float(decay)
        self.prior = min(max(float(prior), 0.0), 0.99)
        self._ema: Dict[int, float] = {}
        self._rung = 0

    @property
    def rung(self) -> int:
        return self._rung

    def note_rung(self, rung: int, prior: Optional[float] = None) -> None:
        """Switch to ``rung``; seed its EMA from ``prior`` if unseen."""
        self._rung = int(rung)
        if self._rung not in self._ema:
            p = self.prior if prior is None else float(prior)
            self._ema[self._rung] = min(max(p, 0.0), 0.99)

    def note_round(self, drafted: int, accepted: int) -> None:
        if drafted <= 0:
            return
        rate = min(max(accepted / drafted, 0.0), 1.0)
        e = self._ema.get(self._rung, self.prior)
        self._ema[self._rung] = self.decay * e + (1.0 - self.decay) * rate

    @property
    def acceptance(self) -> float:
        return self._ema.get(self._rung, self.prior)

    def k(self) -> int:
        """Expected accepted-run length of a geometric(a) stream."""
        a = self.acceptance
        k = int(round(a / max(1.0 - a, 1e-6)))
        return min(max(k, self.k_min), self.k_max)
