"""Token sampling (shared by every serving backend).

`filter_logits` is the single source of truth for the sampling
distribution: temperature scaling, then top-k, then nucleus (top-p)
filtering, each expressed as masking logits to -inf. `sample()` draws
from it; the speculative-decoding rejection sampler
(`repro.specdec.sampler`) consumes the same filtered logits so its
"target distribution" is exactly what autoregressive sampling would
have drawn from — the losslessness contract.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0        # 0 => greedy
    top_k: int = 0                  # 0 => full softmax
    top_p: float = 1.0              # 1 => no nucleus filtering
    seed: int = 0


def filter_logits(logits, cfg: SamplerConfig, real_vocab: int):
    """logits: (..., PV) -> (..., real_vocab) with temperature applied and
    tokens outside the top-k / nucleus set masked to -inf. Only meaningful
    for temperature > 0 (greedy decoding never samples)."""
    lv = logits[..., :real_vocab].astype(jnp.float32)
    if cfg.temperature > 0.0:
        lv = lv / cfg.temperature
    if cfg.top_k and cfg.top_k < real_vocab:
        vals = jax.lax.top_k(lv, cfg.top_k)[0]
        thresh = vals[..., -1:]
        lv = jnp.where(lv >= thresh, lv, NEG_INF)
    if 0.0 < cfg.top_p < 1.0:
        # nucleus: keep the smallest prefix of the descending-prob order
        # whose mass reaches top_p (the head token always survives)
        srt = jnp.sort(lv, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(srt, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        keep = (csum - probs) < cfg.top_p      # mass *before* this token
        # threshold = smallest kept logit; everything below is cut
        thresh = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1,
                         keepdims=True)
        lv = jnp.where(lv >= thresh, lv, NEG_INF)
    return lv


def sample(logits, cfg: SamplerConfig, key, real_vocab: int):
    """logits: (B, PV) -> (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits[:, :real_vocab], axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, filter_logits(logits, cfg, real_vocab)).astype(jnp.int32)
