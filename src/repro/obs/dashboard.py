"""Text dashboard over the SLO engine, scheduler, and flight recorder
(DESIGN.md §17).

One renderer, two modes:

  live     the serving loop calls `tick(now)` between scheduler steps; at
           most once per `interval_s` it returns a text snapshot (SLO
           table with burn rates + breach state, queue/in-flight load,
           rolling critical-path fractions from the tracer ring).
           `snapshot(now)` returns the same state as a JSON-able dict.
  offline  `render_offline(path)` re-renders an exported JSONL trace:
           critical-path decomposition + per-request waterfalls, no live
           objects needed. `python -m repro.obs.dashboard trace.jsonl`
           is the CLI wrapper CI's dashboard smoke drives.

The renderer never touches the process clock: `now` comes from the
caller (virtual seconds in sim runs, wall seconds on the engine).
"""
from __future__ import annotations

import json
from typing import List, Optional

from repro.obs import critical_path as cp


def _fmt_s(v: Optional[float]) -> str:
    return "-" if v is None or v != v else f"{v:7.3f}s"


class Dashboard:
    """Periodic snapshot renderer. All inputs optional — it renders
    whatever it was given a handle to."""

    def __init__(self, *, slo=None, sched=None, tracer=None,
                 interval_s: float = 5.0):
        self.slo = slo
        self.sched = sched
        self.tracer = tracer
        self.interval_s = max(interval_s, 0.0)
        self._last: Optional[float] = None
        self.renders = 0

    # -- cadence -----------------------------------------------------------------
    def due(self, now: float) -> bool:
        return self._last is None or now - self._last >= self.interval_s

    def tick(self, now: float) -> Optional[str]:
        """Render iff the interval elapsed; the serving loop calls this
        every iteration and prints whatever comes back."""
        if not self.due(now):
            return None
        self._last = now
        self.renders += 1
        return self.render(now)

    # -- snapshots ---------------------------------------------------------------
    def snapshot(self, now: float) -> dict:
        out: dict = {"t_s": now}
        if self.slo is not None:
            out["slo"] = self.slo.snapshot(now)
        if self.sched is not None:
            out["load"] = {"queue_depth": self.sched.queue_depth,
                           "in_flight": self.sched.in_flight,
                           "outstanding": self.sched.outstanding}
        if self.tracer is not None:
            per_ns = cp.analyze_all(self.tracer.events())
            out["critical_path"] = {
                ns if ns is not None else "": rep.to_dict()
                for ns, rep in per_ns.items() if rep.rounds}
        return out

    def render(self, now: float) -> str:
        lines: List[str] = [f"== slo dashboard @ t={now:.3f}s " + "=" * 24]
        if self.slo is not None:
            snap = self.slo.snapshot(now)
            lines.append(f"health {snap['health']:.2f}   breaching: "
                         + (", ".join(snap["breaching"]) or "-"))
            lines.append(f"  {'target':<14}{'metric':<9}{'p50':>9}"
                         f"{'p99':>9}{'fast':>7}{'slow':>7}  state")
            for name, t in snap["targets"].items():
                state = "BREACH" if t["breached"] else "ok"
                lines.append(
                    f"  {name:<14}{t['metric']:<9}"
                    f"{_fmt_s(t['p50']):>9}{_fmt_s(t['p99']):>9}"
                    f"{t['fast_burn']:>7.2f}{t['slow_burn']:>7.2f}"
                    f"  {state}")
        if self.sched is not None:
            lines.append(f"load: queue {self.sched.queue_depth}  "
                         f"in-flight {self.sched.in_flight}  "
                         f"outstanding {self.sched.outstanding}")
        if self.tracer is not None:
            for ns, rep in cp.analyze_all(self.tracer.events()).items():
                if not rep.rounds:
                    continue
                fr = rep.fractions
                tag = f" [{ns}]" if ns else ""
                lines.append(
                    f"critical path{tag} ({len(rep.rounds)} rounds): "
                    + "  ".join(f"{k} {100.0 * fr.get(k, 0.0):.0f}%"
                                for k in cp.BUCKETS))
        return "\n".join(lines)


# -- offline ------------------------------------------------------------------
def render_offline(path: str, *, namespace: Optional[str] = None,
                   max_requests: int = 12) -> str:
    """Re-render an exported JSONL trace: critical-path decomposition per
    namespace (or just one), with per-request waterfalls."""
    from repro.obs.exporters import read_jsonl
    _, events = read_jsonl(path)
    if not events:
        return f"(empty trace: {path})"
    blocks: List[str] = []
    spaces = [namespace] if namespace is not None else cp.namespaces(events)
    for ns in spaces:
        rep = cp.analyze(events, namespace=ns)
        if rep.rounds or rep.requests:
            blocks.append(rep.render(max_requests=max_requests))
    return "\n\n".join(blocks) if blocks else \
        f"(no step/request spans in trace: {path})"


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="offline dashboard: critical-path attribution over an "
                    "exported JSONL trace")
    ap.add_argument("trace", help="JSONL trace (Tracer.export *.jsonl)")
    ap.add_argument("--namespace", default=None,
                    help="fleet replica namespace, e.g. r0 (default: all)")
    ap.add_argument("--json", action="store_true",
                    help="emit the JSON snapshot instead of text")
    ap.add_argument("--max-requests", type=int, default=12)
    args = ap.parse_args(argv)
    if args.json:
        from repro.obs.exporters import read_jsonl
        _, events = read_jsonl(args.trace)
        spaces = [args.namespace] if args.namespace is not None \
            else cp.namespaces(events)
        out = {ns if ns is not None else "":
               cp.analyze(events, namespace=ns).to_dict() for ns in spaces}
        print(json.dumps(out, indent=2))
    else:
        print(render_offline(args.trace, namespace=args.namespace,
                             max_requests=args.max_requests))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
