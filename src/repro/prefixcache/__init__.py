"""Radix prefix-cache subsystem (DESIGN.md §12).

A radix tree over token-id sequences whose nodes own full, immutable,
ref-counted KV pages in a `PagePool`: match on admission forks the cached
prefix copy-on-write into a request's BlockTable (only the uncached suffix
is prefilled), insert on finish donates the request's committed pages back,
and LRU eviction reclaims unpinned cached pages first under pool pressure.
"""
from repro.prefixcache.digest import PrefixDigest, chain_hashes  # noqa: F401
from repro.prefixcache.radix import RadixPrefixCache  # noqa: F401
