"""Observability: flight-recorder tracer, exporters, MetricsRegistry,
and the registry-derived ServingReport (DESIGN.md §15)."""
import math

import pytest

from repro.configs.registry import get_config
from repro.core.cost_model import CostEnv, Workload
from repro.core.profiles import env_E3, mbps
from repro.obs import trace as tr_ev
from repro.obs.exporters import (export_jsonl, read_jsonl, to_chrome,
                                 validate_chrome)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import (EVT_ARGS, EVT_DUR, EVT_NAME, EVT_PH, EVT_TRACK,
                             EVT_TS, Tracer, get_tracer, tracing)
from repro.serving import (ContinuousBatchingScheduler, Request,
                           SchedulerConfig, SimBackend, cli_arrivals,
                           requests_from_arrivals, summarize)
from repro.serving.metrics import (SCHEMA_VERSION, percentile,
                                   report_from_dict)


# ----------------------------------------------------------------------------
# Tracer ring semantics
# ----------------------------------------------------------------------------
def test_ring_keeps_last_n_and_counts_drops():
    tr = Tracer(capacity=4, clock=lambda: 0.0)
    for i in range(10):
        tr.instant(f"e{i}", track="t")
    assert len(tr) == 4
    assert tr.emitted == 10
    assert tr.dropped == 6
    # flight-recorder semantics: the LAST events survive
    assert [e[EVT_NAME] for e in tr.events()] == ["e6", "e7", "e8", "e9"]


def test_capacity_must_be_positive():
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_phases_and_explicit_timestamps():
    tr = Tracer(clock=lambda: 7.0)
    tr.instant("a", track="t")                      # clock-stamped
    tr.instant("b", ts=1.5, track="t")              # explicit ts wins
    tr.complete("c", ts=2.0, dur=0.5, track="t")
    tr.complete("neg", ts=2.0, dur=-1.0, track="t")  # clamped, not invalid
    tr.begin("d", track="t")
    tr.end("d", track="t")
    tr.counter("e", track="t", pages=3)
    evs = tr.events()
    assert [e[EVT_PH] for e in evs] == ["i", "i", "X", "X", "B", "E", "C"]
    assert evs[0][EVT_TS] == 7.0
    assert evs[1][EVT_TS] == 1.5
    assert evs[3][EVT_DUR] == 0.0
    assert evs[6][EVT_ARGS] == {"pages": 3}


def test_span_context_manager():
    t = {"now": 1.0}
    tr = Tracer(clock=lambda: t["now"])
    with tr.span("work", track="t"):
        t["now"] = 3.5
    (e,) = tr.events()
    assert e[EVT_PH] == "X" and e[EVT_TS] == 1.0 and e[EVT_DUR] == 2.5


def test_global_install_and_restore():
    assert get_tracer() is None
    with tracing() as tr:
        assert get_tracer() is tr
        with tracing() as inner:
            assert get_tracer() is inner
        assert get_tracer() is tr       # nested install restores previous
    assert get_tracer() is None


# ----------------------------------------------------------------------------
# exporters
# ----------------------------------------------------------------------------
def _sample_tracer():
    tr = Tracer(clock=lambda: 0.0)
    tr.instant(tr_ev.REQ_ARRIVE, ts=0.0, track=tr_ev.req_track(0),
               args={"prompt_len": 8})
    tr.complete(tr_ev.REQ_SPAN, ts=0.0, dur=2.0, track=tr_ev.req_track(0))
    tr.complete(tr_ev.STAGE_COMPUTE, ts=0.1, dur=0.2,
                track=tr_ev.dev_track(1))
    tr.complete(tr_ev.STEP, ts=0.0, dur=0.5, track=tr_ev.TRACK_PIPELINE)
    tr.counter("kv_pages", ts=0.3, track=tr_ev.TRACK_KV, device=4)
    return tr


def test_jsonl_round_trip(tmp_path):
    tr = _sample_tracer()
    p = str(tmp_path / "t.jsonl")
    n = export_jsonl(tr, p)
    assert n == len(tr.events())
    header, evs = read_jsonl(p)
    assert header["schema"] == "lime-trace"
    assert evs == tr.events()           # lossless, in-memory layout


def test_chrome_export_valid_and_track_mapping():
    doc = to_chrome(_sample_tracer())
    assert validate_chrome(doc) == []
    by_name = {}
    for e in doc["traceEvents"]:
        by_name.setdefault(e["name"], []).append(e)
    # pid mapping: req:* -> "requests" (2), dev:* -> "fleet" (1), rest -> 0
    assert by_name[tr_ev.REQ_SPAN][0]["pid"] == 2
    assert by_name[tr_ev.STAGE_COMPUTE][0]["pid"] == 1
    assert by_name[tr_ev.STEP][0]["pid"] == 0
    # seconds -> microseconds
    assert by_name[tr_ev.REQ_SPAN][0]["dur"] == pytest.approx(2e6)
    # metadata names every track
    thread_names = {e["args"]["name"] for e in by_name["thread_name"]}
    assert {"req:0", "dev:1", "pipeline", "kv"} <= thread_names


def test_validate_chrome_catches_problems():
    assert validate_chrome({}) == ["missing top-level 'traceEvents'"]
    bad = {"traceEvents": [
        {"name": "x", "ph": "X", "pid": 0, "tid": 0, "ts": 1.0, "dur": -4},
        {"name": "y", "ph": "E", "pid": 0, "tid": 0, "ts": 1.0},
        {"name": "z", "ph": "B", "pid": 0, "tid": 1, "ts": 1.0},
    ]}
    problems = validate_chrome(bad)
    assert any("dur" in p for p in problems)
    assert any("E without matching B" in p for p in problems)
    assert any("unclosed B" in p for p in problems)


# ----------------------------------------------------------------------------
# MetricsRegistry
# ----------------------------------------------------------------------------
def test_registry_instruments():
    m = MetricsRegistry()
    m.inc("served")
    m.inc("served", 2)
    m.set("adopted", 41.0)
    m.set_gauge("peak_active", 3)
    m.set_gauge("peak_active", 7)
    m.set_gauge("peak_active", 2)       # peak sticks at the high-water mark
    m.set_gauge("depth", 5)
    m.set_gauge("depth", 1)             # non-peak gauge reports last value
    for v in (1.0, 2.0, 3.0, 4.0):
        m.observe("lat", v)
    d = m.to_stats_dict()
    assert d["served"] == 3
    assert d["adopted"] == 41.0
    assert d["peak_active"] == 7
    assert d["depth"] == 1
    assert d["lat_p50"] == 2.0 and d["lat_p99"] == 4.0 and d["lat_count"] == 4
    m.update({"spec_drafted": 10, "spec_accepted": 6})
    assert m.get("spec_drafted") == 10
    assert m.get("missing", -1.0) == -1.0


def test_histogram_percentile_matches_serving_convention():
    m = MetricsRegistry()
    h = m.histogram("x")
    assert math.isnan(h.percentile(50))
    for v in (5.0, 1.0, 3.0):
        h.observe(v)
    for p in (0, 1, 50, 99, 100):
        assert h.percentile(p) == percentile([5.0, 1.0, 3.0], p)


def _finished(rid, arrival, admitted, first, finish, generated):
    r = Request(rid, None, max_new_tokens=generated, arrival_s=arrival,
                prompt_len=16)
    r.admitted_s = admitted
    r.first_token_s = first
    r.finish_s = finish
    r.generated = generated
    r.output = list(range(generated))
    r.done = True
    return r


def test_registry_report_field_identical_to_legacy_dict():
    """The acceptance bar for the stats refactor: summarize() over a
    MetricsRegistry and over the flat dict it replaces produce the same
    ServingReport, field for field."""
    reqs = [_finished(0, 0.0, 0.1, 0.5, 2.0, 8),
            _finished(1, 0.2, 0.3, 0.9, 3.0, 8)]
    legacy = {"peak_active": 2, "peak_kv_pages": 5, "kv_pages_spilled": 1,
              "kv_pages_fetched": 1, "kv_migrated_bytes": 4096.0,
              "spec_rounds": 3, "spec_drafted": 12, "spec_accepted": 9,
              "prefix_lookups": 2, "prefix_hits": 1, "cached_tokens": 64,
              "prefill_tokens_saved": 64, "retier_events": 2,
              "layers_demoted": 1, "layers_promoted": 1,
              "hbm_returned_bytes": 1e6, "retier_reclaimed_pages": 2}
    reg = MetricsRegistry()
    for k, v in legacy.items():
        if k.startswith("peak_"):
            reg.set_gauge(k, v)
        else:
            reg.set(k, v)
    a = summarize(reqs, pattern="p", backend="b", stats=legacy).to_dict()
    b = summarize(reqs, pattern="p", backend="b", stats=reg).to_dict()
    assert set(a) == set(b)
    for k in a:
        if isinstance(a[k], float) and math.isnan(a[k]):
            assert math.isnan(b[k]), k
        else:
            assert a[k] == b[k], k


# ----------------------------------------------------------------------------
# summarize edge cases + schema tolerance
# ----------------------------------------------------------------------------
def test_summarize_nothing_served():
    rep = summarize([])
    assert rep.n_requests == 0 and rep.span_s == 0.0
    assert math.isnan(rep.ms_per_token)
    assert rep.throughput_tok_s == 0.0 and rep.throughput_req_s == 0.0
    assert math.isnan(rep.ttft_p50_s) and math.isnan(rep.latency_p99_s)


def test_summarize_all_rejected():
    reqs = []
    for i in range(3):
        r = Request(i, None, max_new_tokens=4, arrival_s=float(i))
        r.rejected = True
        reqs.append(r)
    rep = summarize(reqs)
    assert rep.n_requests == 0 and rep.n_rejected == 3
    assert math.isnan(rep.ms_per_token)


def test_summarize_missing_admitted_and_first_token():
    """Requests finished without the optional timestamps (older record
    producers): the derived percentiles go NaN, nothing raises."""
    r = _finished(0, 0.0, None, None, 2.0, 4)
    rep = summarize([r])
    assert rep.n_requests == 1
    assert math.isnan(rep.ttft_p50_s)           # no first_token_s
    assert math.isnan(rep.ttft_queue_p50_s)     # no admitted_s
    assert math.isnan(rep.ttft_prefill_p99_s)
    assert math.isnan(rep.decode_tok_s_p50)
    assert rep.latency_p50_s == 2.0             # finish - arrival still real


def test_spec_acceptance_recomputed_from_raw_counters():
    reqs = [_finished(0, 0.0, 0.1, 0.5, 2.0, 8)]
    stats = {"spec_drafted": 10, "spec_accepted": 4,
             "spec_acceptance_rate": 0.99}       # stale copy must lose
    rep = summarize(reqs, stats=stats)
    assert rep.spec_acceptance_rate == pytest.approx(0.4)
    rep0 = summarize(reqs, stats={"spec_drafted": 0, "spec_accepted": 0})
    assert rep0.spec_acceptance_rate == 0.0      # no drafting -> 0, not NaN


def test_report_from_dict_tolerates_old_schema():
    warnings = []

    def warn(msg, **kw):
        warnings.append((msg, kw))

    old = {"pattern": "bursty", "backend": "sim", "n_requests": 4,
           "mystery_field": 1}                   # v0: no schema_version
    rep = report_from_dict(old, source="old.json", warn=warn)
    assert rep.pattern == "bursty" and rep.n_requests == 4
    assert math.isnan(rep.ms_per_token)          # missing float -> NaN
    assert rep.total_tokens == 0                 # missing int -> 0
    msgs = [m for m, _ in warnings]
    assert any("schema mismatch" in m for m in msgs)
    assert any("unknown" in m for m in msgs)
    assert any("missing" in m for m in msgs)

    current = summarize([_finished(0, 0.0, 0.1, 0.5, 2.0, 8)]).to_dict()
    warnings.clear()
    rt = report_from_dict(current, warn=warn)
    assert warnings == []                        # current schema is silent
    assert rt.schema_version == SCHEMA_VERSION


# ----------------------------------------------------------------------------
# percentile nearest-rank boundaries
# ----------------------------------------------------------------------------
def test_percentile_nearest_rank_boundaries():
    assert math.isnan(percentile([], 50))
    assert percentile([7.0], 0) == 7.0
    assert percentile([7.0], 100) == 7.0
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0) == 1.0      # rank clamps at the first element
    assert percentile(xs, 25) == 1.0     # ceil(0.25*4)=1 -> xs[0]
    assert percentile(xs, 50) == 2.0     # ceil(0.5*4)=2  -> xs[1]
    assert percentile(xs, 75) == 3.0
    assert percentile(xs, 99) == 4.0     # ceil(3.96)=4   -> xs[3]
    assert percentile(xs, 100) == 4.0


# ----------------------------------------------------------------------------
# end-to-end: sim serve under the tracer
# ----------------------------------------------------------------------------
def _sim_backend(slots=4, prompt=64):
    cfg = get_config("llama2-13b")
    w = Workload(cfg, mb=1, ctx=prompt, n_micro=slots)
    env = CostEnv(env_E3(), mbps(200), w)
    return SimBackend(env, n_slots=slots, prompt_tokens=prompt)


def _serve_traced(**cfg_kw):
    arrivals = cli_arrivals("bursty", 6, seed=0, prompt_len=64,
                            max_new_tokens=8, gap_s=4.0, burst_size=4)
    with tracing() as tr:
        sched = ContinuousBatchingScheduler(
            _sim_backend(), SchedulerConfig(**cfg_kw))
        done = sched.serve(requests_from_arrivals(arrivals))
    return done, tr


def test_sim_serve_emits_ordered_lifecycle():
    done, tr = _serve_traced()
    evs = tr.events()
    assert all(not r.rejected for r in done)
    by_track = {}
    for e in evs:
        by_track.setdefault(e[EVT_TRACK], []).append(e)
    for r in done:
        lane = by_track[tr_ev.req_track(r.rid)]
        named = {e[EVT_NAME]: e for e in lane}
        # every lifecycle stage present, once each
        for n in (tr_ev.REQ_ARRIVE, tr_ev.REQ_ADMIT, tr_ev.REQ_QUEUE,
                  tr_ev.REQ_PREFILL, tr_ev.REQ_DECODE, tr_ev.REQ_FINISH,
                  tr_ev.REQ_SPAN):
            assert n in named, (r.rid, n)
        # ordering: arrive <= admit <= finish on the virtual clock
        assert named[tr_ev.REQ_ARRIVE][EVT_TS] == r.arrival_s
        assert named[tr_ev.REQ_ARRIVE][EVT_TS] \
            <= named[tr_ev.REQ_ADMIT][EVT_TS] \
            <= named[tr_ev.REQ_FINISH][EVT_TS]
        # nesting: queue + prefill + decode tile the request span
        span = named[tr_ev.REQ_SPAN]
        q, p, d = (named[tr_ev.REQ_QUEUE], named[tr_ev.REQ_PREFILL],
                   named[tr_ev.REQ_DECODE])
        assert q[EVT_TS] == span[EVT_TS]
        assert q[EVT_TS] + q[EVT_DUR] == pytest.approx(p[EVT_TS])
        assert p[EVT_TS] + p[EVT_DUR] == pytest.approx(d[EVT_TS])
        assert d[EVT_TS] + d[EVT_DUR] == pytest.approx(
            span[EVT_TS] + span[EVT_DUR])
        assert span[EVT_DUR] == pytest.approx(r.finish_s - r.arrival_s)
    # step spans on the pipeline track, in virtual time
    steps = [e for e in evs if e[EVT_NAME] == tr_ev.STEP]
    assert steps and all(e[EVT_PH] == "X" and e[EVT_DUR] > 0 for e in steps)
    # per-stage compute spans landed on device lanes
    assert any(e[EVT_NAME] == tr_ev.STAGE_COMPUTE for e in evs)
    # the whole thing renders in Perfetto
    assert validate_chrome(to_chrome(tr)) == []


def test_sim_serve_paged_emits_kv_counters():
    done, tr = _serve_traced(kv_policy="paged", page_size=16)
    assert all(not r.rejected for r in done)
    names = {e[EVT_NAME] for e in tr.events()}
    assert "kv_pages" in names and "active_requests" in names


def test_disabled_tracer_records_nothing():
    assert get_tracer() is None
    sched = ContinuousBatchingScheduler(_sim_backend(), SchedulerConfig())
    assert sched._tr is None            # zero-cost path: sites see None
    arrivals = cli_arrivals("bursty", 4, seed=0, prompt_len=64,
                            max_new_tokens=4, gap_s=4.0, burst_size=4)
    done = sched.serve(requests_from_arrivals(arrivals))
    assert all(not r.rejected for r in done)


def test_tracer_clock_binds_to_backend_virtual_time():
    """Sim traces carry virtual seconds, not wall time: a sim serve's
    events all live inside the run's virtual span."""
    done, tr = _serve_traced()
    t_hi = max(r.finish_s for r in done)
    for e in tr.events():
        assert -1e-9 <= e[EVT_TS] <= t_hi + 1e-9


def test_engine_fallback_serve_traced():
    """Real-execution path (single-device fallback): the same vocabulary
    renders, with engine.* spans on the pipeline track in wall time."""
    jax = pytest.importorskip("jax")
    from repro.configs.registry import get_smoke_config
    from repro.models import model as M
    from repro.serving import EngineBackend, SamplerConfig

    cfg = get_smoke_config("gemma3-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    arrivals = cli_arrivals("bursty", 2, seed=0, prompt_len=8,
                            max_new_tokens=4, gap_s=1.0, burst_size=2)
    with tracing() as tr:
        be = EngineBackend(cfg, params, engine=None, n_slots=2, max_len=32,
                           sampler=SamplerConfig())
        sched = ContinuousBatchingScheduler(be, SchedulerConfig())
        done = sched.serve(
            requests_from_arrivals(arrivals, vocab_size=cfg.vocab_size))
    assert all(not r.rejected for r in done)
    names = {e[EVT_NAME] for e in tr.events()}
    assert tr_ev.ENGINE_PREFILL in names
    assert tr_ev.ENGINE_DECODE in names
    assert tr_ev.REQ_SPAN in names
    assert validate_chrome(to_chrome(tr)) == []
