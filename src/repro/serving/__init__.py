"""LIME-Serve: request serving over the interleaved pipeline (DESIGN.md §9).

Layers, front to back: traffic (arrival generation) -> scheduler
(admission, queueing, continuous batching) -> backend (engine or
discrete-event simulator behind one protocol) -> metrics (TTFT /
latency / throughput reports).
"""
from repro.serving.backend import EngineBackend, SimBackend  # noqa: F401
from repro.serving.metrics import (ServingReport, percentile,  # noqa: F401
                                   summarize)
from repro.serving.sampling import SamplerConfig, sample  # noqa: F401
from repro.serving.scheduler import (ContinuousBatchingScheduler,  # noqa: F401
                                     Request, SchedulerConfig,
                                     requests_from_arrivals)
from repro.serving.server import LimeServer, RequestQueue  # noqa: F401
from repro.serving.traffic import (PATTERNS, ArrivalEvent,  # noqa: F401
                                   cli_arrivals, make_arrivals)
