"""Training loop: pjit'd train_step with ZeRO-1 optimizer sharding.

The train_4k dry-run shape lowers exactly this step. Weights follow the
logical-axis rules (tensor over 'model'); AdamW moments additionally shard
over ('data',) on their largest divisible dim (ZeRO-1) — on the production
mesh that divides optimizer memory by 256.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim.adamw import AdamW, AdamWState, cosine_schedule
from repro.sharding import rules


def zero1_sharding(param_shardings, mesh: Mesh, over=("pod", "data")):
    """Moment sharding: param sharding + shard the largest unsharded dim
    over `over` when divisible (classic ZeRO-1; pass all axes for the
    DP-replicated-weights strategy, where moments are the memory bill)."""
    data = 1
    for a in over:
        data *= mesh.shape.get(a, 1)
    axes = tuple(a for a in over if a in mesh.shape)
    ax = axes if len(axes) > 1 else (axes[0] if axes else None)

    def one(ns: NamedSharding, shape):
        spec = list(ns.spec) + [None] * (len(shape) - len(ns.spec))
        if ax is None or data <= 1:
            return ns
        used = {a for s in spec if s
                for a in (s if isinstance(s, tuple) else (s,))}
        if used & set(axes):
            return ns           # FSDP already shards this leaf over data
        # find largest dim not already sharded, divisible by |data|
        best, best_dim = None, 0
        for i, (d, s) in enumerate(zip(shape, spec)):
            if s is None and d % data == 0 and d > best_dim:
                best, best_dim = i, d
        if best is None:
            return ns
        spec[best] = ax
        return NamedSharding(ns.mesh, P(*spec))
    return one


def make_train_step(cfg: ModelConfig, opt: AdamW, mesh: Optional[Mesh],
                    *, impl: str = "ref", remat: bool = True):
    def train_step(params, opt_state, batch):
        def loss(p):
            l, metrics = M.loss_fn(cfg, p, batch, mesh=mesh, impl=impl,
                                   remat=remat)
            return l, metrics
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        new_params, new_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["loss"] = l
        return new_params, new_state, metrics
    return train_step


@dataclasses.dataclass
class Trainer:
    cfg: ModelConfig
    mesh: Optional[Mesh] = None
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    impl: str = "ref"
    remat: bool = True
    seed: int = 0

    def __post_init__(self):
        self.opt = AdamW(lr=cosine_schedule(self.peak_lr, self.warmup,
                                            self.total_steps))
        self._step_fn = None

    def init(self):
        params = M.init_params(self.cfg, jax.random.PRNGKey(self.seed))
        opt_state = self.opt.init(params)
        if self.mesh is not None:
            specs = M.build_param_specs(self.cfg)
            psh = rules.shardings(specs, self.mesh)
            params = jax.device_put(params, psh)
            z1 = zero1_sharding(None, self.mesh)
            msh = jax.tree.map(
                lambda ns, p: z1(ns, p.shape),
                psh, params)
            opt_state = AdamWState(
                opt_state.step,
                jax.device_put(opt_state.mu, msh),
                jax.device_put(opt_state.nu, msh),
                jax.device_put(opt_state.master, msh))
        return params, opt_state

    def compile(self):
        fn = make_train_step(self.cfg, self.opt, self.mesh, impl=self.impl,
                             remat=self.remat)
        self._step_fn = jax.jit(fn, donate_argnums=(0, 1))
        return self._step_fn

    def fit(self, params, opt_state, batches: Iterator[Dict[str, Any]],
            steps: int, log_every: int = 10,
            log_fn: Optional[Callable[[str], None]] = None):
        """Progress goes through the repro.obs structured logger by
        default (level-gated: quiet under pytest, REPRO_LOG=debug to
        see every line); pass log_fn to capture lines directly."""
        if log_fn is None:
            from repro.obs.log import get_logger
            log_fn = get_logger("repro.training").info
        step_fn = self._step_fn or self.compile()
        history = []
        t0 = time.time()
        for i in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if i % log_every == 0 or i == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                history.append((i, m))
                log_fn(f"step {i:5d}  loss {m['loss']:.4f}  "
                       f"ce {m.get('ce', 0):.4f}  "
                       f"({(time.time() - t0):.1f}s)")
        return params, opt_state, history
