"""Prefix digest: a router-side summary of a radix cache's contents
(DESIGN.md §16).

A fleet router wants to send a request to the replica already holding its
prompt's KV pages, but shipping each replica's whole radix tree (token
tuples!) to the router would cost more than the routing decision saves.
Instead every cached page is summarized by a *cumulative chain hash*:

  cum(node) = H(cum(parent), page_key)

maintained incrementally on insert/evict, so a node's hash pins down the
entire root path — the full token prefix — in one integer. A replica's
digest is just the set of those integers. The router re-derives the same
chain over a candidate prompt's pages and counts the longest run present
in the set: exactly the page-aligned prefix length `RadixPrefixCache.match`
would find, without touching the tree. Hash collisions can only overstate
the overlap (an admission-time `match` still does the exact walk), never
break losslessness.

The digest is also *optimistically extendable*: the router adds the chain
of a prompt it just routed (`add_prompt`) so follow-up requests with the
same template stick to that replica before the first one even finishes.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence

# arbitrary non-zero chain seed (golden-ratio constant) — shared by the
# tree side (radix.py node hashes) and the router side (chain_hashes)
ROOT_SEED = 0x9E3779B97F4A7C15


def chain_hash(parent_cum: int, key: Sequence[int]) -> int:
    """One chain link: H(parent cumulative hash, page token tuple).
    CPython's int/tuple hashing is deterministic (PYTHONHASHSEED only
    perturbs str/bytes), so chains are stable across processes."""
    return hash((parent_cum, tuple(key)))


def chain_hashes(tokens: Sequence[int], page_size: int,
                 max_pages: Optional[int] = None) -> List[int]:
    """Cumulative hash per full page of `tokens` (root chain order)."""
    cap = len(tokens) // page_size
    if max_pages is not None:
        cap = min(cap, max_pages)
    cum, out = ROOT_SEED, []
    for j in range(cap):
        key = tuple(int(t) for t in tokens[j * page_size:(j + 1) * page_size])
        cum = chain_hash(cum, key)
        out.append(cum)
    return out


class PrefixDigest:
    """Set of cumulative page hashes + the page size they were chained at."""
    __slots__ = ("page_size", "_hashes")

    def __init__(self, page_size: int, hashes: Iterable[int] = ()):
        self.page_size = page_size
        self._hashes = set(hashes)

    def __len__(self) -> int:
        return len(self._hashes)

    def __contains__(self, h: int) -> bool:
        return h in self._hashes

    def add_prompt(self, tokens: Sequence[int],
                   max_pages: Optional[int] = None) -> None:
        """Optimistic extension: assume `tokens` is (or will be) cached."""
        self._hashes.update(chain_hashes(tokens, self.page_size, max_pages))

    def match_tokens(self, tokens: Sequence[int],
                     max_pages: Optional[int] = None) -> int:
        """Longest page-aligned prefix of `tokens` present in the digest,
        in tokens — the router's estimate of RadixPrefixCache.match."""
        n = 0
        for h in chain_hashes(tokens, self.page_size, max_pages):
            if h not in self._hashes:
                break
            n += 1
        return n * self.page_size
