"""One fleet member: a scheduler + backend pair with the fleet-facing
surface the router scores on (DESIGN.md §16).

A replica is a full single-pipeline serving stack — its own
`InferenceBackend` (sim or engine, over its own device subset /
`ExecutionPlan`), its own `ContinuousBatchingScheduler`, its own KV pool
and radix cache. The fleet layer never reaches into those; it sees only:

  load        queue_depth / in_flight / free_kv_frac — the router's
              congestion signals, read live between steps
  affinity    digest() — the radix cache's cumulative-hash summary
              (prefixcache/digest.py), what prefix-affinity scores against
  lifecycle   draining / live / retired_s — elastic membership state
              (Fleet.drain / Fleet.join drive these)

step() wraps `scheduler.step()` with this replica's trace namespace and
clock: N replicas share ONE tracer ring, so each step temporarily rewrites
track names to "rK:..." and points the tracer clock at this replica's
backend — the Chrome exporter then renders one Perfetto process group per
replica.
"""
from __future__ import annotations

from typing import List, Optional

from repro.obs.trace import get_tracer
from repro.prefixcache.digest import PrefixDigest
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     SchedulerConfig)


class Replica:
    """A named single-pipeline serving stack inside a fleet."""

    def __init__(self, index: int, backend,
                 config: SchedulerConfig = SchedulerConfig(),
                 name: Optional[str] = None):
        self.index = index
        self.name = name or f"r{index}"
        self.backend = backend
        self.sched = ContinuousBatchingScheduler(backend, config)
        self.draining = False          # admits stopped, in-flight finishing
        self.live = True               # member of the fleet
        self.joined_s = 0.0            # when it entered the score table
        self.retired_s: Optional[float] = None  # drain completed
        self.routed = 0                # requests the router ever sent here

    # -- load signals ------------------------------------------------------------
    def now(self) -> float:
        return self.backend.now()

    @property
    def queue_depth(self) -> int:
        return self.sched.queue_depth

    @property
    def in_flight(self) -> int:
        return self.sched.in_flight

    @property
    def outstanding(self) -> int:
        return self.sched.outstanding

    def health(self) -> float:
        """SLO health in [0, 1] from an attached SLOEngine (DESIGN.md
        §17): 1.0 while every target holds (or no engine is attached), 0
        under runaway burn. The router subtracts w_health * (1 - health)
        from this replica's score, shedding traffic off a breaching
        replica."""
        slo = getattr(self.sched, "slo", None)
        return slo.health if slo is not None else 1.0

    def free_kv_frac(self) -> float:
        """Free device-tier KV as a fraction of capacity (1.0 when the
        replica is not page-managed — no KV pressure signal to score)."""
        if not self.sched.paged:
            return 1.0
        pool = self.sched.mgr.pool
        cap = pool.cfg.device_pages
        return pool.free_pages() / cap if cap > 0 else 1.0

    @property
    def page_size(self) -> int:
        return self.sched.config.page_size

    # -- affinity ----------------------------------------------------------------
    def digest(self) -> Optional[PrefixDigest]:
        """The radix cache's router-side summary; None when no cache."""
        p = self.sched.prefix
        return p.digest() if p is not None else None

    # -- work --------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.routed += 1
        self.sched.submit(req)

    def has_work(self, until: Optional[float] = None) -> bool:
        """True when a step() would make progress: live work, or a pending
        arrival due by `until` (None: ever). Prevents idle replicas from
        jumping their clock past a routing decision the fleet has not made
        yet."""
        s = self.sched
        if s.has_live_work:
            return True
        nxt = s.next_pending_s
        return nxt is not None and (until is None or nxt <= until)

    def step(self) -> bool:
        """One scheduler iteration under this replica's trace namespace
        and clock (restored afterwards — the ring is shared)."""
        tr = get_tracer()
        if tr is None:
            return self.sched.step()
        prev_ns, prev_clock = tr.namespace, tr.clock
        tr.namespace, tr.clock = self.name, self.backend.now
        try:
            return self.sched.step()
        finally:
            tr.namespace, tr.clock = prev_ns, prev_clock

    def finish(self) -> List[Request]:
        """Drain-time accounting for this replica (scheduler.finish_run)."""
        return self.sched.finish_run()

    def __repr__(self) -> str:
        state = "draining" if self.draining else \
            ("live" if self.live else "retired")
        return (f"Replica({self.name}, {state}, q={self.queue_depth}, "
                f"active={self.in_flight}, routed={self.routed})")
