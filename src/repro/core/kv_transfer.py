"""Network bandwidth-sensitive KV cache transfer protocol (paper §IV-D,
Alg. 2, Eq. 8, Fig. 10).

Devices whose weight-loading can't be covered by the pipeline's idle time
("low-threshold" devices) ship the KV cache of their trailing tokens to a
designated high-threshold device `d_target`, segment by segment: the block
for segment s+1 is fetched back asynchronously while segment s computes, so
a transfer only helps if it rides otherwise-idle network time. Eq. 8 sizes
the transfer to exactly the uncovered load window:

    mem(n_i^trans) = (load(L̃_i) − (T_comm + Σ_{i'≠i} comp + comp(L_i−L̃_i))) · bw

Bandwidth dynamics (Alg. 2 lines 8-18): on a bandwidth *drop* the volume is
recomputed immediately (stale volumes would stall the pipeline); on a *rise*
the volume only grows if the device is about to hit its next offload
threshold TS^{j+1} (lazy, avoids thrashing); changes below the fluctuation
threshold `n_ts` tokens are ignored.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.cost_model import CostEnv, ExecutionPlan
from repro.core.online_planner import OnlinePlanner


@dataclasses.dataclass
class TransferState:
    dev_idx: int
    target: Optional[int]          # d_target (None: this device IS a target)
    n_trans: int = 0               # tokens of KV currently delegated
    pending_recompute: bool = False


class KVTransferProtocol:
    def __init__(self, env: CostEnv, plan: ExecutionPlan, planner: OnlinePlanner,
                 *, n_ts: int = 16):
        self.env = env
        self.plan = plan
        self.planner = planner
        self.n_ts = n_ts
        self.bw = env.bw_net
        self.states = self._assign_targets()

    # -- Fig. 10: pair low-threshold devices with high-threshold targets ------
    def _assign_targets(self) -> List[TransferState]:
        D = len(self.plan.stages)
        thresholds = []
        for i in range(D):
            t = self.planner.next_threshold(i)
            thresholds.append(float("inf") if t is None else t)
        order = sorted(range(D), key=lambda i: thresholds[i])
        median = thresholds[order[D // 2]] if D else 0
        states = []
        # high-threshold half serve as targets, round-robin for the low half
        highs = [i for i in order if thresholds[i] >= median] or order[-1:]
        h = 0
        for i in range(D):
            if thresholds[i] >= median and i in highs:
                states.append(TransferState(i, None))
            else:
                states.append(TransferState(i, highs[h % len(highs)]))
                h += 1
        return states

    # -- Eq. 8 -----------------------------------------------------------------
    def eq8_tokens(self, i: int, bw: Optional[float] = None,
                   ctx_tokens: int = 0) -> int:
        bw = self.bw if bw is None else bw
        st = self.states[i]
        if st.target is None:
            return 0
        d = self.plan.stages[i]
        w = self.env.work
        load = self.env.load_time(
            i, d.load_bytes_seg(w) + self.planner.extra_load_bytes_seg(i))
        idle = self.env.idle_seg(self.plan, i)
        uncovered = max(load - idle, 0.0)
        kv_tok = self.planner._kv_per_token(i)
        if kv_tok <= 0:
            return 0
        n = int(uncovered * bw // kv_tok)
        if ctx_tokens:
            n = min(n, int(0.8 * ctx_tokens))   # can't ship KV we don't have
        return n

    # -- Alg. 2 lines 8-18: bandwidth reaction ----------------------------------
    def on_bandwidth(self, new_bw: float, total_tokens: int) -> Dict[int, int]:
        """Returns {dev: new n_trans} for devices whose volume changed."""
        changed = {}
        for st in self.states:
            if st.target is None:
                continue
            n_new = self.eq8_tokens(st.dev_idx, new_bw)
            if abs(n_new - st.n_trans) < self.n_ts:
                continue                                   # line 14: ignore
            if new_bw < self.bw:                           # drop: immediate
                st.n_trans = n_new
                changed[st.dev_idx] = n_new
            else:                                          # rise: lazy
                ts_next = self.planner.next_threshold(st.dev_idx)
                near = ts_next is not None and \
                    total_tokens + st.n_trans >= ts_next - 1
                if near:                                   # lines 15-17
                    st.n_trans = n_new
                    changed[st.dev_idx] = n_new
        self.bw = new_bw
        return changed

    # -- per-step effects used by the simulator ---------------------------------
    def init_transfers(self, ctx_tokens: int = 0) -> None:
        for st in self.states:
            st.n_trans = self.eq8_tokens(st.dev_idx, ctx_tokens=ctx_tokens)

    def refresh(self, ctx_tokens: int) -> None:
        """Re-solve Eq. 8 as KV pressure (and hence planner-added load)
        grows — the paper's feedback loop: more uncovered load -> more KV
        delegated -> bottleneck thresholds delayed. Volumes only grow here
        (shrinking is the bandwidth-drop path, `on_bandwidth`)."""
        for st in self.states:
            if st.target is None:
                continue
            n = self.eq8_tokens(st.dev_idx, ctx_tokens=ctx_tokens)
            if n > st.n_trans + self.n_ts:
                st.n_trans = n

    def load_reduction_bytes_seg(self, i: int) -> float:
        """Weight-load bytes per segment the delegated KV frees on device i:
        the vacated memory pins offloaded blocks resident ((#Seg-1) copies
        per pinned block — Eq. 7's factor)."""
        st = self.states[i]
        if st.target is None or st.n_trans == 0:
            return 0.0
        # the slab is away during exactly the segments whose weights must
        # stream in, so the vacated bytes pin weight blocks 1:1
        return st.n_trans * self.planner._kv_per_token(i)

    def transferred_tokens(self, i: int) -> int:
        return self.states[i].n_trans

    def transfer_time_seg(self, i: int) -> float:
        """Per-segment wire time of the delegated KV slab (ride-along; the
        simulator overlaps it with compute like the weight loads)."""
        st = self.states[i]
        if st.target is None or st.n_trans == 0:
            return 0.0
        kv_tok = self.planner._kv_per_token(i)
        return (st.n_trans * kv_tok / max(self.plan.n_seg, 1)) / self.bw

    def effective_kv_tokens(self, i: int, total_tokens: int) -> int:
        """KV tokens resident on device i after delegation (n - n_i^trans)."""
        return max(total_tokens - self.states[i].n_trans, 0)

    # -- Eq. 8 volumes as page movement (DESIGN.md §10) --------------------------
    def delegated_pages(self, page_size: int) -> int:
        """Fleet-wide delegated volume in whole pages (floor — a page only
        moves when every slot in it is delegated)."""
        total = sum(st.n_trans for st in self.states if st.target is not None)
        return total // max(page_size, 1)

    def sync_pool(self, pool) -> float:
        """Reconcile an attached PagePool's host tier with the current
        Eq. 8 volumes: delegated tokens -> pages resident on the host
        ("delegated") tier. Called by the simulator every step after
        refresh()/on_bandwidth(); returns bytes moved so the caller can
        price the wire (the volume is sized to ride idle network time, so
        it adds traffic, not latency). Best-effort: clamped to the pages
        actually in use and the host tier's capacity."""
        from repro.kvcache.pool import HOST, DEVICE
        target = self.delegated_pages(pool.page_size)
        # can't delegate KV that doesn't exist: Eq. 8 sums per-device
        # volumes over the fleet, the pool holds the admitted streams
        total = pool.pages_in_use(HOST) + pool.pages_in_use(DEVICE)
        target = min(target, total)
        cur = pool.pages_in_use(HOST)
        if target > cur:
            return pool.migrate_any(target - cur, HOST)
        if target < cur:                    # bandwidth drop shrank Eq. 8
            return pool.migrate_any(cur - target, DEVICE)
        return 0.0
