"""Online SLO engine (DESIGN.md §17): sketch error bounds (incl. after
merge), burn-rate window algebra, breach/recover hysteresis, health ->
router/planner wiring, and critical-path conservation on a seeded sim."""
import json
import math
import types

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.core.cost_model import CostEnv, Workload
from repro.core.profiles import env_E3, mbps
from repro.obs import critical_path as cp
from repro.obs.sketch import (EWMA, P2Quantile, ReservoirSketch,
                              WindowedCounter, reservoir_rank_error)
from repro.obs.slo import SLOEngine, SLOTarget, default_targets
from repro.obs.trace import Tracer, set_tracer, tracing
from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                           SimBackend, make_arrivals,
                           requests_from_arrivals)
from repro.serving.metrics import percentile


# ----------------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------------
def _exact_rank(xs_sorted, v):
    """Fraction of the population strictly below v (rank of v)."""
    import bisect
    return bisect.bisect_left(xs_sorted, v) / len(xs_sorted)


def _req(arrival, first, finish, generated=4, rejected=False):
    return types.SimpleNamespace(arrival_s=arrival, first_token_s=first,
                                 finish_s=finish, generated=generated,
                                 rejected=rejected)


def _lat_target(**kw):
    base = dict(threshold_s=1.0, target=0.5, fast_window_s=10.0,
                slow_window_s=30.0, burn_threshold=1.5,
                recovery_frac=0.5)
    base.update(kw)
    return SLOTarget("lat_p50", "latency", **base)


# ----------------------------------------------------------------------------
# ReservoirSketch: documented rank-error bound, exact small-n, merge
# ----------------------------------------------------------------------------
def test_reservoir_exact_below_capacity():
    s = ReservoirSketch(64, seed=1)
    vals = [float(v) for v in (9, 1, 5, 3, 7)]
    s.extend(vals)
    # below capacity the reservoir IS the population: every quantile
    # matches the exact serving-convention nearest-rank answer
    for p in (0, 25, 50, 75, 99, 100):
        assert s.quantile(p) == percentile(vals, p)
    assert s.count == 5


def test_reservoir_rank_error_bound_beyond_capacity():
    import numpy as np
    rng = np.random.default_rng(7)
    xs = rng.lognormal(mean=0.0, sigma=0.8, size=20000).tolist()
    s = ReservoirSketch(512, seed=3)
    s.extend(xs)
    xs_sorted = sorted(xs)
    eps = reservoir_rank_error(512)
    for p in (25, 50, 75, 90, 99):
        est = s.quantile(p)
        assert abs(_exact_rank(xs_sorted, est) - p / 100.0) <= eps, p
    # extremes are tracked exactly, not sampled
    assert s.quantile(0) == min(xs)
    assert s.quantile(100) == max(xs)


def test_reservoir_rank_error_bound_survives_merge():
    import numpy as np
    rng = np.random.default_rng(11)
    # two disjoint regimes: merged percentiles are only right if the
    # merge re-samples proportionally to population counts
    a = rng.normal(1.0, 0.1, size=12000).tolist()
    b = rng.normal(5.0, 0.2, size=4000).tolist()
    sa, sb = ReservoirSketch(512, seed=5), ReservoirSketch(512, seed=6)
    sa.extend(a)
    sb.extend(b)
    sa.merge(sb)
    pooled = sorted(a + b)
    assert sa.count == len(pooled)
    eps = reservoir_rank_error(512)
    for p in (50, 75, 90, 99):
        est = sa.quantile(p)
        assert abs(_exact_rank(pooled, est) - p / 100.0) <= eps, p


@settings(max_examples=30)
@given(st.lists(st.floats(min_value=-1e3, max_value=1e3), min_size=0,
                max_size=40),
       st.lists(st.floats(min_value=-1e3, max_value=1e3), min_size=0,
                max_size=40),
       st.integers(min_value=1, max_value=16))
def test_reservoir_merge_invariants(xs, ys, cap):
    """Property: after any merge, the reservoir is a <=cap-sized subset of
    the pooled population with exact count/min/max, and every quantile
    lies inside the pooled [min, max]."""
    a, b = ReservoirSketch(cap, seed=1), ReservoirSketch(cap, seed=2)
    a.extend(xs)
    b.extend(ys)
    a.merge(b)
    pooled = xs + ys
    assert a.count == len(pooled)
    assert len(a.samples) <= cap
    if pooled:
        pool_set = sorted(pooled)
        for v in a.samples:
            assert v in pooled
        assert a.quantile(0) == min(pooled)
        assert a.quantile(100) == max(pooled)
        q = a.quantile(50)
        assert pool_set[0] <= q <= pool_set[-1]
    else:
        assert math.isnan(a.quantile(50))


def test_reservoir_merge_exact_when_everything_fits():
    a, b = ReservoirSketch(16, seed=0), ReservoirSketch(16, seed=0)
    a.extend([1.0, 2.0])
    b.extend([3.0, 4.0, 5.0])
    a.merge(b)
    assert sorted(a.samples) == [1.0, 2.0, 3.0, 4.0, 5.0]
    assert a.quantile(50) == percentile([1.0, 2.0, 3.0, 4.0, 5.0], 50)


# ----------------------------------------------------------------------------
# P2 / EWMA
# ----------------------------------------------------------------------------
def test_p2_quantile_tracks_smooth_stream():
    import numpy as np
    rng = np.random.default_rng(3)
    xs = rng.normal(10.0, 2.0, size=8000).tolist()
    p2 = P2Quantile(q=0.9)
    for v in xs:
        p2.observe(v)
    exact = percentile(xs, 90)
    # empirical gate: ~2x the reservoir bound on a smooth stream
    assert abs(_exact_rank(sorted(xs), p2.value()) - 0.9) \
        <= 2 * reservoir_rank_error(512)
    assert abs(p2.value() - exact) / exact < 0.05


def test_ewma_halflife_and_rate():
    e = EWMA(half_life_s=10.0)
    assert math.isnan(e.value())
    e.update(100.0, now=0.0)
    assert e.value(0.0) == 100.0
    e.update(0.0, now=10.0)       # old sample decayed to weight 0.5
    assert e.value(10.0) == pytest.approx(100.0 * 0.5 / 1.5)
    # rate: weight 1.5 over effective window 10/ln2
    assert e.rate(10.0) == pytest.approx(1.5 / (10.0 / math.log(2.0)))


# ----------------------------------------------------------------------------
# WindowedCounter: the burn-rate window algebra
# ----------------------------------------------------------------------------
def test_windowed_counter_trailing_windows():
    w = WindowedCounter(60.0, n_buckets=60)      # 1s buckets
    w.add(0.0, good=1.0)
    w.add(20.0, bad=2.0)
    w.add(25.0, good=3.0)
    # fast window (10s @ t=25) sees t=20 and t=25, not t=0
    good, bad = w.totals(10.0, 25.0)
    assert (good, bad) == (3.0, 2.0)
    # slow window (60s) sees everything
    good, bad = w.totals(60.0, 25.0)
    assert (good, bad) == (4.0, 2.0)
    assert w.bad_fraction(10.0, 25.0) == pytest.approx(2.0 / 5.0)


def test_windowed_counter_quantization_bound():
    # documented algebra: a window of W covers between W and W + bucket
    # seconds — an event just past W may still be counted, one past
    # W + bucket never is
    w = WindowedCounter(60.0, n_buckets=60)      # bucket = 1s
    w.add(0.5, bad=1.0)
    assert w.totals(10.0, 10.4)[1] == 1.0        # 9.9s old: inside
    assert w.totals(10.0, 11.6)[1] == 0.0        # 11.1s > W + bucket: out


def test_windowed_counter_expiry_and_empty():
    w = WindowedCounter(30.0, n_buckets=30)
    w.add(0.0, bad=5.0)
    assert w.bad_fraction(30.0, 0.0) == 1.0
    # ring fully rolled over: everything expired
    assert w.totals(30.0, 100.0) == (0.0, 0.0)
    assert w.bad_fraction(30.0, 100.0) == 0.0    # idle burns no budget


# ----------------------------------------------------------------------------
# SLOEngine: breach fires / clears at the documented thresholds
# ----------------------------------------------------------------------------
def test_breach_needs_both_windows():
    eng = SLOEngine([_lat_target(fast_window_s=5.0, slow_window_s=30.0)])
    # seed the slow window with good traffic so slow burn stays low
    for i in range(20):
        eng.observe_request(_req(i, i + 0.1, i + 0.5), now=float(i + 1))
    assert eng.breaching == []
    # burst of bad inside the fast window only: fast burn spikes, slow
    # burn stays under threshold -> still no breach (two-window rule)
    for i in range(9):
        t = 20.2 + 0.2 * i
        eng.observe_request(_req(t - 2.5, t - 2.0, t), now=t)
    fast, slow = eng.burn_rates("lat_p50", 22.0)
    assert fast >= 1.5 and slow < 1.5
    assert eng.breaching == []
    assert eng.health == 1.0


def test_breach_and_recovery_hysteresis():
    tr = Tracer(capacity=256)
    set_tracer(tr)
    try:
        eng = SLOEngine([_lat_target()])
        # sustained bad traffic: both windows burn at 2.0 >= 1.5
        for i in range(8):
            t = float(i + 1)
            eng.observe_request(_req(t - 3.0, t - 2.5, t), now=t)
        assert eng.breaching == ["lat_p50"]
        st = eng.snapshot(8.0)["targets"]["lat_p50"]
        assert st["breached"] and st["breaches"] == 1
        # health at burn 2.0 / threshold 1.5: 1/(1 + 4/3) = 3/7
        assert eng.health == pytest.approx(1.0 / (1.0 + 2.0 / 1.5))
        assert eng.pressure() == pytest.approx(1.0 - eng.health)
        # good traffic ages the bad out of the fast (10s) window; breach
        # clears only once fast burn < threshold x recovery_frac = 0.75
        for i in range(30):
            t = 9.0 + i
            eng.observe_request(_req(t - 0.5, t - 0.4, t), now=t)
        assert eng.breaching == []
        snap = eng.snapshot(40.0)["targets"]["lat_p50"]
        assert snap["recoveries"] == 1
        assert eng.health == 1.0
        names = [e[0] for e in tr.events()]
        assert "slo.breach" in names and "slo.recover" in names
    finally:
        set_tracer(None)


def test_reject_target_counts_sheds():
    eng = SLOEngine([SLOTarget("rej", "reject", target=0.5,
                               fast_window_s=10.0, slow_window_s=10.0,
                               burn_threshold=1.5)])
    for i in range(4):
        eng.observe_reject(_req(0, None, None, rejected=True),
                           now=float(i))
    assert eng.breaching == ["rej"]          # 100% shed, budget 0.5
    assert eng.snapshot(4.0)["targets"]["rej"]["observed"] == 0


def test_target_validation():
    with pytest.raises(ValueError):
        SLOTarget("x", "not_a_metric")
    with pytest.raises(ValueError):
        SLOTarget("x", "ttft", target=1.0)
    with pytest.raises(ValueError):
        SLOTarget("x", "ttft", fast_window_s=60.0, slow_window_s=30.0)
    with pytest.raises(ValueError):
        SLOEngine([_lat_target(), _lat_target()])
    assert {t.name for t in default_targets()} == \
        {"ttft_p99", "tpot_p50", "goodput_p95", "reject_rate"}


def test_snapshot_is_json_clean():
    eng = SLOEngine([_lat_target()])
    s = json.dumps(eng.snapshot(0.0), allow_nan=False)   # no NaN leaks
    d = json.loads(s)
    assert d["targets"]["lat_p50"]["p50"] is None        # nothing observed


# ----------------------------------------------------------------------------
# scheduler wiring: attach_slo feeds finishes/rejects, health reaches planner
# ----------------------------------------------------------------------------
def _backend(slots=2, prompt=64):
    cfg = get_config("llama2-13b")
    w = Workload(cfg, mb=1, ctx=prompt, n_micro=slots)
    return SimBackend(CostEnv(env_E3(), mbps(200.0), w), n_slots=slots,
                      prompt_tokens=prompt)


def test_scheduler_feeds_slo_engine():
    sched = ContinuousBatchingScheduler(_backend(), SchedulerConfig())
    eng = SLOEngine()                        # loose defaults: no breach
    sched.attach_slo(eng)
    arr = make_arrivals("bursty", 4, seed=0, prompt_len=64,
                        max_new_tokens=4, gap_s=5.0, burst_size=2)
    done = sched.serve(requests_from_arrivals(arr, seed=0))
    snap = eng.snapshot(sched.now())
    assert snap["targets"]["ttft_p99"]["observed"] == len(done)
    assert snap["targets"]["ttft_p99"]["p50"] > 0
    assert eng.breaching == []


def test_slo_pressure_reaches_backend():
    calls = []
    backend = _backend()
    backend.note_slo_pressure = lambda p: calls.append(p)
    sched = ContinuousBatchingScheduler(backend, SchedulerConfig())
    sched.attach_slo(SLOEngine([_lat_target(threshold_s=1e-9)]))
    arr = make_arrivals("bursty", 4, seed=0, prompt_len=64,
                        max_new_tokens=4, gap_s=5.0, burst_size=2)
    sched.serve(requests_from_arrivals(arr, seed=0))
    # impossible threshold -> every finish is bad -> breach -> pressure
    assert calls and max(calls) > 0.0


# ----------------------------------------------------------------------------
# critical path: conservation + request decomposition on a seeded sim
# ----------------------------------------------------------------------------
def test_critical_path_conservation_seeded_sim():
    with tracing(capacity=1 << 16) as tr:
        sched = ContinuousBatchingScheduler(_backend(), SchedulerConfig())
        arr = make_arrivals("bursty", 4, seed=0, prompt_len=64,
                            max_new_tokens=4, gap_s=5.0, burst_size=2)
        done = sched.serve(requests_from_arrivals(arr, seed=0))
        rep = cp.analyze(tr.events())
    assert rep.rounds, "traced run must produce STEP rounds"
    # every round's buckets sum to the measured round time within 1%
    assert rep.conservation_error() < 0.01
    for r in rep.rounds:
        assert sum(r.buckets.values()) == pytest.approx(r.dur, rel=1e-6)
        assert min(r.buckets.values()) >= 0.0
        assert r.bottleneck.startswith("dev:")
    fr = rep.fractions
    assert sum(fr.values()) == pytest.approx(1.0)
    assert fr["compute"] > 0.5               # E3/13B is compute-dominated
    # request decomposition: queue + buckets == end-to-end, exactly
    assert len(rep.requests) == len(done)
    for rq in rep.requests:
        assert rq.queue_s + sum(rq.buckets.values()) \
            == pytest.approx(rq.total_s, rel=1e-9)
    # renderers stay well-formed
    assert "critical path" in rep.render()
    assert rep.to_dict()["totals"]["compute"] > 0


def test_critical_path_namespace_split():
    assert cp.split_track("r2:dev:3") == ("r2", "dev:3")
    assert cp.split_track("dev:3") == (None, "dev:3")
    ev = [("step", "X", 0.0, 1.0, "r0:pipeline", {}),
          ("step", "X", 0.0, 1.0, "r1:pipeline", {})]
    assert cp.namespaces(ev) == ["r0", "r1"]
    per = cp.analyze_all(ev)
    assert set(per) == {"r0", "r1"}
    assert all(len(r.rounds) == 1 for r in per.values())
