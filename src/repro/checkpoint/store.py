"""Sharded checkpointing: flat-key npz shards + json manifest.

Each host writes its addressable shards; restore re-shards onto the current
mesh (NamedSharding-aware via jax.device_put). Works single-host with any
mesh (the dry-run environment) and degrades gracefully to plain arrays.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


def save(path: str, tree, step: Optional[int] = None) -> None:
    """Write this host's shard (`shard<process_index>.npz`) plus the
    manifest. Multi-host runs call save() on every process; each writes
    its own shard file and process 0's manifest wins (identical keys)."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "keys": {}}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        safe = k.replace("/", "__")
        dtype = str(arr.dtype)
        if dtype == "bfloat16":                 # npz can't store bf16
            arr = arr.astype(np.float32)
        arrays[safe] = arr
        manifest["keys"][k] = {"shape": list(arr.shape), "dtype": dtype}
    np.savez(os.path.join(path, f"shard{jax.process_index()}.npz"),
             **arrays)
    if jax.process_index() == 0:
        with open(os.path.join(path, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)


def restore(path: str, shardings=None):
    """Merge every `shard*.npz` under `path` (first occurrence of a key
    wins — hosts write identical replicated keys) and re-shard onto the
    current mesh when `shardings` is given."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    shards = sorted(glob.glob(os.path.join(path, "shard*.npz")))
    if not shards:
        raise FileNotFoundError(f"no shard*.npz under {path}")
    flat = {}
    for shard in shards:
        data = np.load(shard)
        for k, meta in manifest["keys"].items():
            safe = k.replace("/", "__")
            if k in flat or safe not in data:
                continue
            arr = data[safe]
            if meta["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.astype(ml_dtypes.bfloat16)
            flat[k] = arr
    missing = set(manifest["keys"]) - set(flat)
    if missing:
        raise KeyError(f"manifest keys missing from shards: "
                       f"{sorted(missing)[:5]}...")
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, manifest.get("step")
