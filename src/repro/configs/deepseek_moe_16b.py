"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed top-6, first layer
dense. [arXiv:2401.06066]"""
from repro.configs.base import ModelConfig, Family, AttnKind

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family=Family.MOE,
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944,          # dense first-layer d_ff
    moe_d_ff=1408,       # fine-grained expert d_ff
    vocab_size=102400, head_dim=128,
    n_experts=64, n_shared_experts=2, top_k=6, first_dense_layers=1,
    attn_kind=AttnKind.FULL,
    source="DeepSeekMoE [arXiv:2401.06066]",
)
