"""Online re-fit: serving telemetry -> live CostEnv -> ladder rebuild.

A measured profile is stale the moment the device thermal-throttles or a
neighbour starts hammering the SSD. This module closes the loop during
serving: EWMA estimators (repro.obs.sketch, on the serving clock) track
the *observed* weight-fetch bandwidth and stage-compute speed per device
— the same quantities the `weight.fetch` / `stage.compute` tracer spans
carry — and when the observation drifts more than `drift_tol` (default
20%) from what the planned CostEnv assumes, the planned env's device is
updated to the measured value and the OnlinePlanner's TS ladders are
rebuilt against it.

The rebuild passes `chunk_scale` = measured/planned load bandwidth, so a
slowed loader plans smaller demotion chunks (less extra streaming per
segment) instead of blindly keeping the sized-for-fast-SSD plan — the
mechanism that keeps an injected bandwidth drift from turning into
admission preemptions (bench_autotune part 3).

Updates are applied *in place* on `env.devices` so every holder of the
env (sim, planner, KV protocol, scheduler) sees the re-fit without
reference rewiring; `CostEnv.replace_device` exists for callers that
want a copy instead.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.cost_model import CostEnv
from repro.obs import trace as tr_ev
from repro.obs.log import get_logger
from repro.obs.sketch import EWMA
from repro.obs.trace import get_tracer


@dataclasses.dataclass(frozen=True)
class RefitConfig:
    drift_tol: float = 0.20    # rebuild when |measured/planned - 1| exceeds
    half_life_s: float = 2.0   # EWMA half-life on the serving clock
    min_samples: int = 4       # per-device observations before trusting
    cooldown_s: float = 1.0    # min clock time between rebuilds


@dataclasses.dataclass(frozen=True)
class RefitEvent:
    now: float
    dev_idx: int
    field: str                 # "load_bw" | "flops"
    planned: float
    measured: float

    @property
    def ratio(self) -> float:
        return self.measured / self.planned if self.planned > 0 else 1.0


class OnlineRefit:
    """Per-device drift estimators + the planned-env update rule."""

    def __init__(self, env: CostEnv, planner=None, *,
                 config: RefitConfig = RefitConfig()):
        self.env = env
        self.planner = planner
        self.cfg = config
        if not isinstance(env.devices, list):
            env.devices = list(env.devices)   # in-place updates need a list
        n = len(env.devices)
        self._bw = [EWMA(config.half_life_s) for _ in range(n)]
        self._bw_n = [0] * n
        # compute speed as planned_time / observed_time (> 1 = faster)
        self._comp = [EWMA(config.half_life_s) for _ in range(n)]
        self._comp_n = [0] * n
        self._last_refit = -float("inf")
        self.events: List[RefitEvent] = []

    # -- observations ----------------------------------------------------------
    def observe_fetch(self, i: int, nbytes: float, seconds: float, *,
                      now: float) -> None:
        """One weight-fetch completion on device i's loader channel."""
        if seconds > 0 and nbytes > 0:
            self._bw[i].update(nbytes / seconds, now)
            self._bw_n[i] += 1

    def observe_compute(self, i: int, seconds: float,
                        planned_seconds: float, *, now: float) -> None:
        """One stage-compute completion: observed vs planned-model time."""
        if seconds > 0 and planned_seconds > 0:
            self._comp[i].update(planned_seconds / seconds, now)
            self._comp_n[i] += 1

    def consume_events(self, events) -> int:
        """Ingest tracer events (the `weight.fetch` spans on
        "dev:<i>:loader" tracks carry bytes + duration); returns the
        number consumed. The sim feeds observations directly — this path
        serves replay/offline analysis of an exported trace."""
        n = 0
        for e in events:
            if e[tr_ev.EVT_NAME] != tr_ev.WEIGHT_FETCH:
                continue
            track = e[tr_ev.EVT_TRACK]
            args = e[tr_ev.EVT_ARGS] or {}
            if not (track.startswith("dev:") and track.endswith(":loader")):
                continue
            try:
                i = int(track.split(":")[1])
            except ValueError:
                continue
            if 0 <= i < len(self.env.devices) and "bytes" in args:
                self.observe_fetch(i, float(args["bytes"]),
                                   float(e[tr_ev.EVT_DUR]),
                                   now=float(e[tr_ev.EVT_TS]
                                             + e[tr_ev.EVT_DUR]))
                n += 1
        return n

    # -- drift readout ---------------------------------------------------------
    def drift(self, i: int) -> Dict[str, float]:
        """{field: measured/planned} for device i, only for fields with
        enough samples to trust."""
        out: Dict[str, float] = {}
        dev = self.env.devices[i]
        if self._bw_n[i] >= self.cfg.min_samples and dev.load_bw > 0:
            out["load_bw"] = self._bw[i].value() / dev.load_bw
        if self._comp_n[i] >= self.cfg.min_samples:
            out["flops"] = self._comp[i].value()
        return out

    # -- the update rule -------------------------------------------------------
    def maybe_refit(self, now: float) -> List[RefitEvent]:
        """Fold any out-of-tolerance drift into the planned env and
        rebuild the planner's ladders once per call at most. Returns the
        RefitEvents applied (empty inside cooldown or within tolerance)."""
        if now - self._last_refit < self.cfg.cooldown_s:
            return []
        fired: List[RefitEvent] = []
        scales: List[float] = []
        for i, dev in enumerate(self.env.devices):
            d = self.drift(i)
            updates = {}
            if "load_bw" in d and abs(d["load_bw"] - 1.0) > self.cfg.drift_tol:
                measured = self._bw[i].value()
                updates["load_bw"] = measured
                fired.append(RefitEvent(now, i, "load_bw", dev.load_bw,
                                        measured))
                scales.append(d["load_bw"])
            if "flops" in d and abs(d["flops"] - 1.0) > self.cfg.drift_tol:
                measured = dev.flops * d["flops"]
                updates["flops"] = measured
                fired.append(RefitEvent(now, i, "flops", dev.flops,
                                        measured))
            if updates:
                # in-place so every env holder sees the re-fit
                self.env.devices[i] = dataclasses.replace(dev, **updates)
        if not fired:
            return []
        self._last_refit = now
        self.events.extend(fired)
        chunk_scale = min(scales) if scales else 1.0
        if self.planner is not None:
            self.planner.rebuild(self.env, chunk_scale=chunk_scale)
        log = get_logger("repro.tune")
        tr = get_tracer()
        for ev in fired:
            log.info("online re-fit applied", dev=ev.dev_idx, field=ev.field,
                     planned=f"{ev.planned:.3g}",
                     measured=f"{ev.measured:.3g}",
                     ratio=f"{ev.ratio:.2f}")
            if tr is not None:
                tr.instant(tr_ev.TUNE_REFIT, ts=now, track=tr_ev.TRACK_TUNE,
                           args={"dev": ev.dev_idx, "field": ev.field,
                                 "planned": ev.planned,
                                 "measured": ev.measured,
                                 "chunk_scale": chunk_scale})
        return fired

    @property
    def n_refits(self) -> int:
        return len(self.events)
