"""MeasuredProfile: a DeviceProfile whose numbers were *timed*, not typed.

`repro.core.profiles` is explicit that its constants are "knobs, not
measurements" — every plan the offline scheduler emits and every TS
ladder the online planner walks inherits that uncertainty. A
MeasuredProfile carries the same fields the cost model prices (so it
flows through CostEnv / allocate / OnlinePlanner unchanged) plus
provenance: where the numbers came from, when, how many trials, and a
per-field confidence (coefficient of variation across trials — the
harness reports it so a consumer can tell a tight measurement from a
noisy one).

JSON round-trip follows the repo convention (DESIGN.md §17): NaN is not
valid JSON, so unknown confidences serialize as null and come back as
NaN (`to_dict` / `from_dict` are exact inverses on every non-NaN field).

`check_sane` is the poisoned-cache guard: a measured field more than
SANITY_FACTOR (3x) away from its analytic counterpart usually means a
broken clock, an interpret-mode run timed as if it were hardware, or a
unit slip — it logs a warning through `repro.obs.log` rather than
failing, because a genuinely 4x-faster device is possible and the plan
comparison benchmarks decide what wins.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Mapping, Optional

from repro.core.profiles import DeviceProfile
from repro.obs.log import get_logger

SANITY_FACTOR = 3.0

# the DeviceProfile fields the harness measures / the cost model prices
MEASURED_FIELDS = ("flops", "mem_bw", "load_bw", "load_write_bw", "host_bw")


@dataclasses.dataclass(frozen=True)
class MeasuredProfile(DeviceProfile):
    """DeviceProfile + measurement provenance. `confidence` maps a
    measured field name to its coefficient of variation across trials
    (NaN = not measured this run, e.g. a field adopted from the analytic
    base)."""
    device_kind: str = ""          # jax device_kind / platform, cache key
    source: str = "measured"       # measured | cache | synthetic
    measured_at: str = ""          # ISO-8601, provenance only
    n_trials: int = 0
    confidence: Mapping[str, float] = dataclasses.field(default_factory=dict)
    extras: Mapping[str, float] = dataclasses.field(default_factory=dict)
    # extras: raw harness observations that don't map onto a priced field
    # (decode tok/s, prefill seconds, insert bandwidth, ...) — provenance
    # for humans and benchmarks, never consumed by the cost model

    # -- JSON ------------------------------------------------------------------
    @staticmethod
    def _null_nan(m: Mapping) -> Dict:
        return {k: (None if isinstance(v, float) and math.isnan(v) else v)
                for k, v in dict(m).items()}

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["confidence"] = self._null_nan(self.confidence)
        d["extras"] = self._null_nan(self.extras)
        return d

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), allow_nan=False, **kw)

    @classmethod
    def from_dict(cls, d: Mapping) -> "MeasuredProfile":
        d = dict(d)
        for key in ("confidence", "extras"):
            d[key] = {k: (float("nan") if v is None else float(v))
                      for k, v in dict(d.get(key) or {}).items()}
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    # -- sanity ----------------------------------------------------------------
    def deviation(self, analytic: DeviceProfile) -> Dict[str, float]:
        """measured / analytic per priced field (only fields both sides
        have non-zero; a 0-vs-0 field is in agreement, not a deviation)."""
        out = {}
        for f in MEASURED_FIELDS:
            a, m = getattr(analytic, f), getattr(self, f)
            if a > 0 and m > 0:
                out[f] = m / a
        return out

    def check_sane(self, analytic: DeviceProfile, *,
                   factor: float = SANITY_FACTOR) -> Dict[str, float]:
        """Warn (repro.obs.log) on any measured field > `factor`x away
        from the analytic counterpart in either direction; returns the
        offending {field: ratio} map so callers/tests can assert on it."""
        log = get_logger("repro.tune")
        bad = {f: r for f, r in self.deviation(analytic).items()
               if r > factor or r < 1.0 / factor}
        for f, r in sorted(bad.items()):
            log.warning("measured profile deviates from analytic",
                        device=self.name, kind=self.device_kind, field=f,
                        ratio=f"{r:.3g}", factor=factor,
                        hint="broken clock / interpret-mode timing?")
        return bad


def from_analytic(base: DeviceProfile, *, device_kind: str,
                  source: str = "synthetic",
                  **overrides) -> MeasuredProfile:
    """Lift an analytic profile into a MeasuredProfile, overriding the
    fields a measurement (or a replayed drift) supplies. Fields not
    overridden keep the analytic value and get confidence NaN."""
    vals = {f.name: getattr(base, f.name)
            for f in dataclasses.fields(DeviceProfile)}
    vals.update(overrides)
    conf = {f: (0.0 if f in overrides else float("nan"))
            for f in MEASURED_FIELDS}
    return MeasuredProfile(device_kind=device_kind, source=source,
                           confidence=conf, **vals)
