"""Structured, level-gated logging for the repro (DESIGN.md §15).

Replaces the `print`-based progress lines in the training and serving
paths. Built on stdlib `logging` with two repo conventions:

  level gate   REPRO_LOG=debug|info|warning|error overrides; otherwise
               INFO normally, WARNING under pytest (test output stays
               clean — the suite asserts on stdout in places).
  structure    `log.info("admitted", rid=3, pages=7)` renders
               "admitted rid=3 pages=7" — grep-stable key=value pairs
               instead of ad-hoc f-strings.
"""
from __future__ import annotations

import logging
import os
import sys
from typing import Dict

_LEVELS = {"debug": logging.DEBUG, "info": logging.INFO,
           "warning": logging.WARNING, "error": logging.ERROR}


def _default_level() -> int:
    env = os.environ.get("REPRO_LOG", "").lower()
    if env in _LEVELS:
        return _LEVELS[env]
    # quiet by default under pytest: progress lines would interleave with
    # captured assertions
    if "PYTEST_CURRENT_TEST" in os.environ or "pytest" in sys.modules:
        return logging.WARNING
    return logging.INFO


class StructuredLogger:
    """Thin kwargs->key=value wrapper over one stdlib logger."""

    def __init__(self, logger: logging.Logger):
        self._log = logger

    @staticmethod
    def _fmt(msg: str, kw: Dict) -> str:
        if not kw:
            return msg
        return msg + " " + " ".join(f"{k}={v}" for k, v in kw.items())

    def debug(self, msg: str, **kw) -> None:
        self._log.debug(self._fmt(msg, kw))

    def info(self, msg: str, **kw) -> None:
        self._log.info(self._fmt(msg, kw))

    def warning(self, msg: str, **kw) -> None:
        self._log.warning(self._fmt(msg, kw))

    def error(self, msg: str, **kw) -> None:
        self._log.error(self._fmt(msg, kw))

    def set_level(self, level: str) -> None:
        self._log.setLevel(_LEVELS[level.lower()])

    def is_enabled_for(self, level: str) -> bool:
        return self._log.isEnabledFor(_LEVELS[level.lower()])


_cache: Dict[str, StructuredLogger] = {}


def get_logger(name: str = "repro") -> StructuredLogger:
    """Process-cached structured logger. First call per name wires a
    stderr handler and the gated default level."""
    lg = _cache.get(name)
    if lg is not None:
        return lg
    raw = logging.getLogger(name)
    if not raw.handlers:
        h = logging.StreamHandler()
        h.setFormatter(logging.Formatter(
            "[%(levelname).1s %(name)s] %(message)s"))
        raw.addHandler(h)
        raw.setLevel(_default_level())
        raw.propagate = False
    lg = _cache[name] = StructuredLogger(raw)
    return lg
