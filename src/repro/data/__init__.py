from repro.data.pipeline import SyntheticCorpus, PackedBatches, \
    make_batches  # noqa: F401
