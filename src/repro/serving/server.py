"""Serving layer: request queue, batch scheduler, sampler.

Maps the paper's two request patterns onto the engine:
  sporadic — requests arrive singly; the engine runs with n_mb = 1 and the
             pipeline drains between requests (paper Fig. 3).
  bursty   — up to n_mb = n_stage requests are co-scheduled as micro-batches
             filling the interleaved pipeline (paper Fig. 4).

The scheduler is deliberately simple (FIFO + fixed micro-batch slots): the
paper's contribution is *below* this layer; anything fancier (continuous
batching) would obscure the reproduction. Prefill runs through the plain
model path on replicated/GSPMD-sharded params, then the caches are adopted
into the engine layout (`engine.seed_cache`).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.core.engine import InterleavedEngine


# ----------------------------------------------------------------------------
# Sampler
# ----------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0        # 0 => greedy
    top_k: int = 0                  # 0 => full softmax
    seed: int = 0


def sample(logits, cfg: SamplerConfig, key, real_vocab: int):
    """logits: (B, PV) -> (B,) int32."""
    lv = logits[:, :real_vocab]
    if cfg.temperature <= 0.0:
        return jnp.argmax(lv, axis=-1).astype(jnp.int32)
    lv = lv / cfg.temperature
    if cfg.top_k:
        vals, idx = jax.lax.top_k(lv, cfg.top_k)
        choice = jax.random.categorical(key, vals)
        return jnp.take_along_axis(idx, choice[:, None], 1)[:, 0] \
            .astype(jnp.int32)
    return jax.random.categorical(key, lv).astype(jnp.int32)


# ----------------------------------------------------------------------------
# Requests
# ----------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int
    arrival_s: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None


class RequestQueue:
    def __init__(self):
        self._q: deque[Request] = deque()
        self._next = 0

    def submit(self, prompt, max_new_tokens: int, now: float = 0.0) -> Request:
        r = Request(self._next, np.asarray(prompt, np.int32),
                    max_new_tokens, arrival_s=now)
        self._next += 1
        self._q.append(r)
        return r

    def pop_up_to(self, n: int) -> List[Request]:
        out = []
        while self._q and len(out) < n:
            out.append(self._q.popleft())
        return out

    def __len__(self):
        return len(self._q)


# ----------------------------------------------------------------------------
# Server
# ----------------------------------------------------------------------------
class LimeServer:
    """Batch scheduler over an InterleavedEngine (or a plain single-host
    decode fallback when engine is None — used by quickstart on 1 device)."""

    def __init__(self, cfg: ModelConfig, params, *,
                 engine: Optional[InterleavedEngine] = None,
                 max_len: int = 512, sampler: SamplerConfig = SamplerConfig(),
                 pattern: str = "sporadic"):
        self.cfg = cfg
        self.params = params
        self.engine = engine
        self.max_len = max_len
        self.sampler = sampler
        self.pattern = pattern
        self.queue = RequestQueue()
        self._key = jax.random.PRNGKey(sampler.seed)
        self._prefill = jax.jit(functools.partial(M.prefill, cfg))
        self._decode = jax.jit(functools.partial(M.decode_step, cfg)) \
            if engine is None else None

    @property
    def slots(self) -> int:
        if self.engine is None:
            return 1 if self.pattern == "sporadic" else 4
        return 1 if self.pattern == "sporadic" else self.engine.n_mb

    def _pad_prompts(self, reqs: List[Request]):
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt     # left-pad
        return jnp.asarray(toks)

    def step_batch(self, reqs: List[Request]) -> List[Request]:
        """Run one co-scheduled batch of requests to completion."""
        B_needed = self.engine.n_mb * self.engine.mb if self.engine else \
            len(reqs)
        reqs = list(reqs)
        real = len(reqs)
        toks = self._pad_prompts(reqs)
        if toks.shape[0] < B_needed:               # pad batch with replicas
            toks = jnp.concatenate(
                [toks, jnp.tile(toks[-1:], (B_needed - real, 1))], 0)

        cache = M.init_cache(self.cfg, toks.shape[0], self.max_len)
        logits, cache = self._prefill(self.params, toks, cache)
        t0 = time.time()

        if self.engine is not None:
            state = self.engine.init_state(self.params)
            state = self.engine.seed_cache(state, cache)
            step = lambda st, t: self.engine.decode_step(st, t)
        else:
            state = cache
            step = lambda st, t: _swap(self._decode(self.params, st, t))

        max_new = max(r.max_new_tokens for r in reqs)
        self._key, k = jax.random.split(self._key)
        tok = sample(logits[:, -1], self.sampler, k, self.cfg.vocab_size)
        for i, r in enumerate(reqs):
            r.output.append(int(tok[i]))
            r.first_token_s = time.time() - t0
        cur = tok[:, None]
        for n in range(1, max_new):
            lg, state = step(state, cur)
            if lg.ndim == 3:
                lg = lg[:, 0]
            self._key, k = jax.random.split(self._key)
            tok = sample(lg, self.sampler, k, self.cfg.vocab_size)
            cur = tok[:, None]
            for i, r in enumerate(reqs):
                if len(r.output) < r.max_new_tokens:
                    r.output.append(int(tok[i]))
        for r in reqs:
            r.done = True
            r.finish_s = time.time() - t0
        return reqs

    def serve_all(self) -> List[Request]:
        """Drain the queue according to the request pattern."""
        finished = []
        while len(self.queue):
            batch = self.queue.pop_up_to(self.slots)
            finished.extend(self.step_batch(batch))
        return finished


def _swap(pair):
    logits, state = pair
    return logits[:, 0], state
